//! The Eagle router: global + local ELO scoring (paper §2.2).
//!
//! ```text
//! Score(X) = P * Global(X) + (1 - P) * Local(X)
//! ```
//!
//! - **Eagle-Global**: one ELO table over every pairwise feedback record;
//!   updated incrementally as feedback arrives (never retrained).
//! - **Eagle-Local**: per query, retrieve the N nearest historical feedback
//!   entries by embedding cosine similarity, seed a fresh ELO table from
//!   the global ratings ("background knowledge"), and replay just those N
//!   records.
//!
//! `P = 1` is the Eagle-Global ablation, `P = 0` Eagle-Local (Fig 4a);
//! `N` sweeps give Fig 4b.

use crate::config::EagleParams;
use crate::elo::{Comparison, EloEngine, GlobalElo};
use crate::vectordb::{BatchTopK, Feedback, Hit, ReadIndex, VectorIndex};

use super::Router;

/// Replay already-retrieved neighbors through a seeded local engine,
/// trajectory-averaging into `sum`. `engine` must be freshly (re)seeded
/// from the global averages and `sum` initialized to them; both are left
/// dirty for the caller to reuse.
///
/// Neighbors are replayed in *ascending* similarity order so the closest
/// prompts' feedback lands last and carries the most weight in the
/// sequential ELO update (EXPERIMENTS.md ablation), and the replay is
/// trajectory-averaged like Eagle-Global.
fn replay_neighbors<R: ReadIndex + ?Sized>(
    index: &R,
    hits: &[Hit],
    engine: &mut EloEngine,
    sum: &mut [f64],
) {
    let mut samples = 1u64;
    for hit in hits.iter().rev() {
        for &c in &index.feedback(hit.id).comparisons {
            engine.update(c);
            for (s, &r) in sum.iter_mut().zip(engine.ratings()) {
                *s += r;
            }
            samples += 1;
        }
    }
    for s in sum.iter_mut() {
        *s /= samples as f64;
    }
}

/// Local ELO ratings for one query over any read-only index:
/// global-seeded, neighbor-replayed, trajectory-averaged.
///
/// This is the scoring core shared by [`EagleRouter`] (mutable store) and
/// [`super::snapshot::RouterSnapshot`] (immutable view): both call the
/// exact same code over the exact same stored data, which is what makes
/// the locked-vs-snapshot score-equivalence tests bit-exact.
pub fn local_ratings_from<R: ReadIndex + ?Sized>(
    params: &EagleParams,
    global_avg: &[f64],
    index: &R,
    query_emb: &[f32],
) -> Vec<f64> {
    let mut local = EloEngine::seeded(global_avg.to_vec(), params.k_factor);
    let hits = index.search(query_emb, params.n_neighbors);
    let mut sum = global_avg.to_vec();
    replay_neighbors(index, &hits, &mut local, &mut sum);
    sum
}

/// Combined Eagle scores (paper Eq. `Score(X) = P*G + (1-P)*L`) from
/// precomputed trajectory-averaged global ratings and a read-only index.
pub fn mixed_scores_from<R: ReadIndex + ?Sized>(
    params: &EagleParams,
    global_avg: &[f64],
    index: &R,
    query_emb: &[f32],
) -> Vec<f64> {
    if params.p >= 1.0 {
        // pure global: skip retrieval entirely
        return global_avg.to_vec();
    }
    let local = local_ratings_from(params, global_avg, index, query_emb);
    global_avg
        .iter()
        .zip(&local)
        .map(|(g, l)| params.p * g + (1.0 - params.p) * l)
        .collect()
}

/// Reusable scoring scratch for the batch route path: the batch search
/// selectors/tile, the retrieved hit lists, and the local-replay engine +
/// trajectory buffer. One of these per batch replaces the seed path's
/// per-query `TopK` + hits + `EloEngine` + sum allocations.
#[derive(Default)]
pub struct ScoreScratch {
    acc: BatchTopK,
    hits: Vec<Vec<Hit>>,
    engine: Option<EloEngine>,
    sum: Vec<f64>,
}

impl ScoreScratch {
    pub fn new() -> Self {
        ScoreScratch::default()
    }
}

/// (Re)build the scratch engine only when the model arity or K-factor
/// changed; otherwise the existing allocation is reseeded per query.
fn ensure_engine<'a>(
    engine: &'a mut Option<EloEngine>,
    params: &EagleParams,
    global_avg: &[f64],
) -> &'a mut EloEngine {
    let stale = match engine.as_ref() {
        None => true,
        Some(e) => e.n_models() != global_avg.len() || e.k() != params.k_factor,
    };
    if stale {
        *engine = Some(EloEngine::seeded(global_avg.to_vec(), params.k_factor));
    }
    engine.as_mut().expect("engine just ensured")
}

/// Combined Eagle scores for one query whose neighbor list was already
/// retrieved (the sharded gather merges per-shard candidates first).
/// Bit-identical to [`mixed_scores_from`] fed the same hits; reuses the
/// scratch engine/buffers instead of allocating per query.
pub(crate) fn mixed_scores_from_hits<R: ReadIndex + ?Sized>(
    params: &EagleParams,
    global_avg: &[f64],
    index: &R,
    hits: &[Hit],
    scratch: &mut ScoreScratch,
) -> Vec<f64> {
    if params.p >= 1.0 {
        return global_avg.to_vec();
    }
    let engine = ensure_engine(&mut scratch.engine, params, global_avg);
    let sum = &mut scratch.sum;
    engine.reseed_from(global_avg);
    sum.clear();
    sum.extend_from_slice(global_avg);
    replay_neighbors(index, hits, engine, sum);
    global_avg
        .iter()
        .zip(sum.iter())
        .map(|(g, l)| params.p * g + (1.0 - params.p) * l)
        .collect()
}

/// Batch counterpart of [`mixed_scores_from`]: one query-blocked
/// retrieval pass over the index scores the whole batch (the corpus
/// streams through the kernel once per
/// [`crate::vectordb::kernel::QUERY_TILE`] queries instead of once per
/// query), and the local replay reuses one scratch engine/buffer set
/// across the batch. Scores are bit-identical to mapping
/// [`mixed_scores_from`] per query.
pub fn mixed_scores_batch_from<R: ReadIndex + ?Sized>(
    params: &EagleParams,
    global_avg: &[f64],
    index: &R,
    queries: &[&[f32]],
    scratch: &mut ScoreScratch,
) -> Vec<Vec<f64>> {
    if params.p >= 1.0 {
        return queries.iter().map(|_| global_avg.to_vec()).collect();
    }
    let ScoreScratch { acc, hits, engine, sum } = scratch;
    index.search_batch_into(queries, params.n_neighbors, acc);
    acc.drain_hits_into(hits);
    let engine = ensure_engine(engine, params, global_avg);
    let mut out = Vec::with_capacity(queries.len());
    for hits_q in hits.iter().take(queries.len()) {
        engine.reseed_from(global_avg);
        sum.clear();
        sum.extend_from_slice(global_avg);
        replay_neighbors(index, hits_q, engine, sum);
        out.push(
            global_avg
                .iter()
                .zip(sum.iter())
                .map(|(g, l)| params.p * g + (1.0 - params.p) * l)
                .collect(),
        );
    }
    out
}

/// All pairwise feedback collected for one prompt, tied to its embedding.
#[derive(Debug, Clone)]
pub struct Observation {
    pub embedding: Vec<f32>,
    pub comparisons: Vec<Comparison>,
}

impl Observation {
    pub fn single(embedding: Vec<f32>, comparison: Comparison) -> Self {
        Observation { embedding, comparisons: vec![comparison] }
    }
}

/// The Eagle router over a pluggable vector index.
pub struct EagleRouter<I: VectorIndex + Send> {
    params: EagleParams,
    n_models: usize,
    global: GlobalElo,
    store: I,
}

impl<I: VectorIndex + Send> EagleRouter<I> {
    /// Empty router (cold start: uniform global ratings, empty store).
    pub fn new(params: EagleParams, n_models: usize, store: I) -> Self {
        let global = GlobalElo::new(n_models, params.k_factor);
        EagleRouter { params, n_models, global, store }
    }

    /// Initialize from a feedback history (paper: "training-free" setup —
    /// one ELO replay plus vector inserts, no optimization loop).
    pub fn fit(params: EagleParams, n_models: usize, store: I, history: &[Observation]) -> Self {
        let mut router = EagleRouter::new(params, n_models, store);
        router.update(history);
        router
    }

    /// Incremental online update (the paper's 100-200x cheaper path):
    /// O(new) ELO updates + O(new) vector inserts. No retraining.
    pub fn update(&mut self, new_observations: &[Observation]) {
        for obs in new_observations {
            self.global.apply_new(&obs.comparisons);
            self.store
                .add(&obs.embedding, Feedback { comparisons: obs.comparisons.clone() });
        }
    }

    /// Ingest one prompt's feedback (server path).
    pub fn observe(&mut self, obs: Observation) {
        self.global.apply_new(&obs.comparisons);
        self.store.add(&obs.embedding, Feedback { comparisons: obs.comparisons });
    }

    /// Overwrite the global table from snapshot state (see
    /// [`super::state`]); replay order is already folded into `ratings`.
    pub fn restore_global(&mut self, ratings: &[f64], history_len: usize) {
        assert_eq!(ratings.len(), self.n_models, "rating arity");
        self.global = GlobalElo::restore(ratings.to_vec(), self.params.k_factor, history_len);
    }

    /// Rebuild this router over a different store representation, keeping
    /// the global ELO state (including its averaging trajectory) intact.
    /// Used to move a flat-store router onto the segmented snapshot store
    /// at server bring-up.
    pub fn map_store<J, F>(self, f: F) -> EagleRouter<J>
    where
        J: VectorIndex + Send,
        F: FnOnce(I) -> J,
    {
        EagleRouter {
            params: self.params,
            n_models: self.n_models,
            global: self.global,
            store: f(self.store),
        }
    }

    pub fn params(&self) -> &EagleParams {
        &self.params
    }

    pub fn n_models(&self) -> usize {
        self.n_models
    }

    pub fn global(&self) -> &GlobalElo {
        &self.global
    }

    pub fn store(&self) -> &I {
        &self.store
    }

    /// Mutable store access (snapshot publication freezes through this).
    pub fn store_mut(&mut self) -> &mut I {
        &mut self.store
    }

    pub fn feedback_len(&self) -> usize {
        self.global.history_len()
    }

    /// The N retrieved neighbors for a query (diagnostics / tests).
    pub fn neighbors(&self, query_emb: &[f32]) -> Vec<Hit> {
        self.store.search(query_emb, self.params.n_neighbors)
    }

    /// Local ELO ratings for a query: global-seeded, neighbor-replayed
    /// (see [`local_ratings_from`] for the shared core).
    pub fn local_ratings(&self, query_emb: &[f32]) -> Vec<f64> {
        local_ratings_from(&self.params, &self.global.ratings(), &self.store, query_emb)
    }

    /// Combined Eagle scores (paper Eq. Score(X) = P*G + (1-P)*L).
    pub fn combined_scores(&self, query_emb: &[f32]) -> Vec<f64> {
        mixed_scores_from(&self.params, &self.global.ratings(), &self.store, query_emb)
    }

    /// Score a whole batch of queries against one consistent state:
    /// the trajectory-averaged global table is computed once, retrieval
    /// runs the query-blocked kernel scan, and the local replay reuses
    /// one scratch buffer set across the batch — bit-identical scores to
    /// mapping [`EagleRouter::combined_scores`] per query.
    pub fn score_batch(&self, query_embs: &[Vec<f32>]) -> Vec<Vec<f64>> {
        let global = self.global.ratings();
        let queries: Vec<&[f32]> = query_embs.iter().map(|q| q.as_slice()).collect();
        let mut scratch = ScoreScratch::new();
        mixed_scores_batch_from(&self.params, &global, &self.store, &queries, &mut scratch)
    }
}

impl EagleRouter<crate::vectordb::view::SegmentStore> {
    /// Bulk-ingest one sealed block (a mapped v2 segment from the durable
    /// store): the global table folds each record's comparisons in order
    /// — the exact per-record updates [`EagleRouter::observe`] performs —
    /// while the store adopts the embedding slab as one zero-copy sealed
    /// segment instead of copying row by row.
    pub(crate) fn absorb_block(
        &mut self,
        slab: crate::vectordb::view::Slab,
        feedbacks: Vec<Feedback>,
    ) {
        for fb in &feedbacks {
            self.global.apply_new(&fb.comparisons);
        }
        self.store.push_block(slab, feedbacks);
    }
}

impl<I: VectorIndex + Send> Router for EagleRouter<I> {
    fn name(&self) -> String {
        match self.params.p {
            p if p >= 1.0 => "eagle-global".to_string(),
            p if p <= 0.0 => "eagle-local".to_string(),
            _ => "eagle".to_string(),
        }
    }

    fn scores(&self, query_emb: &[f32]) -> Vec<f64> {
        self.combined_scores(query_emb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elo::Outcome;
    use crate::util::{l2_normalize, Rng};
    use crate::vectordb::flat::FlatStore;

    const DIM: usize = 16;

    fn unit(rng: &mut Rng) -> Vec<f32> {
        let mut v: Vec<f32> = (0..DIM).map(|_| rng.normal() as f32).collect();
        l2_normalize(&mut v);
        v
    }

    fn near(base: &[f32], rng: &mut Rng, eps: f32) -> Vec<f32> {
        let mut v: Vec<f32> = base.iter().map(|&x| x + eps * rng.normal() as f32).collect();
        l2_normalize(&mut v);
        v
    }

    fn params(p: f64, n: usize) -> EagleParams {
        EagleParams { p, n_neighbors: n, k_factor: 32.0 }
    }

    /// Build a history with a *global* winner (model 0) but a *local*
    /// specialist (model 2 wins inside a cluster around `anchor`).
    fn specialist_history(rng: &mut Rng, anchor: &[f32]) -> Vec<Observation> {
        let mut hist = Vec::new();
        for _ in 0..300 {
            let emb = unit(rng);
            let b = 1 + rng.below(2); // 1 or 2
            hist.push(Observation::single(
                emb,
                Comparison { a: 0, b, outcome: Outcome::WinA },
            ));
        }
        for _ in 0..60 {
            let emb = near(anchor, rng, 0.05);
            hist.push(Observation::single(
                emb,
                Comparison { a: 2, b: 0, outcome: Outcome::WinA },
            ));
        }
        // interleave: an ordered stream (all specialist wins last) would
        // legitimately push the specialist to the top of the *global* table
        rng.shuffle(&mut hist);
        hist
    }

    #[test]
    fn fit_builds_global_and_store() {
        let mut rng = Rng::new(1);
        let anchor = unit(&mut rng);
        let hist = specialist_history(&mut rng, &anchor);
        let router =
            EagleRouter::fit(params(0.5, 20), 3, FlatStore::new(DIM), &hist);
        assert_eq!(router.feedback_len(), hist.len());
        assert_eq!(router.store().len(), hist.len());
        // model 0 dominates globally
        assert_eq!(router.global().ranking()[0], 0);
    }

    #[test]
    fn local_detects_specialist() {
        let mut rng = Rng::new(2);
        let anchor = unit(&mut rng);
        let hist = specialist_history(&mut rng, &anchor);
        let router =
            EagleRouter::fit(params(0.0, 20), 3, FlatStore::new(DIM), &hist);
        // near the anchor, local ELO must rank the specialist (2) first
        let probe = near(&anchor, &mut rng, 0.02);
        let scores = router.scores(&probe);
        let best = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(best, 2, "scores = {scores:?}");
    }

    #[test]
    fn global_ignores_locality() {
        let mut rng = Rng::new(3);
        let anchor = unit(&mut rng);
        let hist = specialist_history(&mut rng, &anchor);
        let router =
            EagleRouter::fit(params(1.0, 20), 3, FlatStore::new(DIM), &hist);
        let probe = near(&anchor, &mut rng, 0.02);
        let far = unit(&mut rng);
        assert_eq!(router.scores(&probe), router.scores(&far));
        assert_eq!(router.name(), "eagle-global");
    }

    #[test]
    fn combined_interpolates() {
        let mut rng = Rng::new(4);
        let anchor = unit(&mut rng);
        let hist = specialist_history(&mut rng, &anchor);
        let store = FlatStore::new(DIM);
        let router = EagleRouter::fit(params(0.5, 20), 3, store, &hist);
        let probe = near(&anchor, &mut rng, 0.02);

        let global = router.global().ratings().to_vec();
        let local = router.local_ratings(&probe);
        let combined = router.combined_scores(&probe);
        for m in 0..3 {
            let expect = 0.5 * global[m] + 0.5 * local[m];
            assert!((combined[m] - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn incremental_update_shifts_ratings() {
        let mut rng = Rng::new(5);
        let mut router =
            EagleRouter::new(params(0.5, 10), 3, FlatStore::new(DIM));
        let before = router.global().ratings().to_vec();
        let obs: Vec<Observation> = (0..50)
            .map(|_| {
                Observation::single(
                    unit(&mut rng),
                    Comparison { a: 1, b: 2, outcome: Outcome::WinA },
                )
            })
            .collect();
        router.update(&obs);
        assert!(router.global().ratings()[1] > before[1]);
        assert!(router.global().ratings()[2] < before[2]);
        assert_eq!(router.store().len(), 50);
    }

    #[test]
    fn update_equals_fit_on_concatenation() {
        // the incremental-vs-retrain equivalence behind Table 3a
        let mut rng = Rng::new(6);
        let anchor = unit(&mut rng);
        let hist = specialist_history(&mut rng, &anchor);
        let (old, new) = hist.split_at(200);

        let mut incr =
            EagleRouter::fit(params(0.5, 20), 3, FlatStore::new(DIM), old);
        incr.update(new);
        let full = EagleRouter::fit(params(0.5, 20), 3, FlatStore::new(DIM), &hist);

        let probe = near(&anchor, &mut rng, 0.02);
        let a = incr.scores(&probe);
        let b = full.scores(&probe);
        for m in 0..3 {
            assert!((a[m] - b[m]).abs() < 1e-9, "{a:?} vs {b:?}");
        }
    }

    #[test]
    fn neighbors_limited_to_n() {
        let mut rng = Rng::new(7);
        let anchor = unit(&mut rng);
        let hist = specialist_history(&mut rng, &anchor);
        let router =
            EagleRouter::fit(params(0.5, 5), 3, FlatStore::new(DIM), &hist);
        assert_eq!(router.neighbors(&anchor).len(), 5);
    }

    #[test]
    fn empty_router_scores_uniform() {
        let router = EagleRouter::new(params(0.5, 20), 4, FlatStore::new(DIM));
        let q = vec![1.0; DIM];
        let s = router.scores(&q);
        assert_eq!(s, vec![crate::elo::INITIAL_RATING; 4]);
    }

    #[test]
    fn score_batch_matches_singles() {
        let mut rng = Rng::new(9);
        let anchor = unit(&mut rng);
        let hist = specialist_history(&mut rng, &anchor);
        let router = EagleRouter::fit(params(0.5, 20), 3, FlatStore::new(DIM), &hist);
        let queries: Vec<Vec<f32>> = (0..8).map(|_| unit(&mut rng)).collect();
        let batch = router.score_batch(&queries);
        assert_eq!(batch.len(), queries.len());
        for (q, b) in queries.iter().zip(&batch) {
            assert_eq!(&router.scores(q), b, "batch path must be bit-identical");
        }
    }

    #[test]
    fn observe_single_record() {
        let mut rng = Rng::new(8);
        let mut router = EagleRouter::new(params(0.5, 20), 3, FlatStore::new(DIM));
        router.observe(Observation::single(
            unit(&mut rng),
            Comparison { a: 0, b: 1, outcome: Outcome::WinB },
        ));
        assert_eq!(router.feedback_len(), 1);
        assert!(router.global().ratings()[1] > router.global().ratings()[0]);
    }
}
