//! Read-only file mappings via raw `mmap(2)`.
//!
//! Sealed segment files in the v2 fixed layout keep their embeddings as one
//! contiguous, 64-byte-aligned run of little-endian f32 bits, so a mapped
//! file can be scored straight from the page cache: no per-record decode, no
//! heap copy. This is what makes `DurableStore::open` O(segment count)
//! instead of O(corpus bytes).
//!
//! On non-unix hosts — or whenever a map attempt fails — callers fall back
//! to reading the file into an owned buffer and decoding it; [`SlabRef`]s
//! are only ever constructed over a real mapping, so the unsafe f32 view
//! below never sees an unaligned heap allocation.

use std::fs::File;
use std::io;
use std::sync::Arc;

#[cfg(unix)]
mod sys {
    //! mmap/munmap via raw declarations (`std` already links libc on unix,
    //! so the `extern` declarations below add no dependency).

    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        pub fn munmap(addr: *mut u8, len: usize) -> i32;
    }
}

/// A read-only, private mapping of an entire file.
///
/// The mapping stays valid even if the file is later unlinked (POSIX keeps
/// the pages alive until the last unmap), which is what lets the compactor's
/// GC delete superseded segment files while recovered views still reference
/// them.
pub struct Mapping {
    ptr: *const u8,
    len: usize,
}

// The mapping is PROT_READ and never mutated after construction, so sharing
// the view across threads is safe.
unsafe impl Send for Mapping {}
unsafe impl Sync for Mapping {}

impl Mapping {
    /// Map `file` read-only in its entirety. Empty files map to an empty
    /// view without touching the syscall.
    #[cfg(unix)]
    pub fn map(file: &File) -> io::Result<Mapping> {
        use std::os::unix::io::AsRawFd;

        let len = file.metadata()?.len();
        let len = usize::try_from(len)
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "file too large to map"))?;
        if len == 0 {
            return Ok(Mapping { ptr: std::ptr::null(), len: 0 });
        }
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        // MAP_FAILED is (void*)-1.
        if ptr.is_null() || ptr as usize == usize::MAX {
            return Err(io::Error::last_os_error());
        }
        Ok(Mapping { ptr, len })
    }

    /// Stub for non-unix hosts: callers treat the error as "fall back to
    /// buffered decode".
    #[cfg(not(unix))]
    pub fn map(_file: &File) -> io::Result<Mapping> {
        Err(io::Error::new(io::ErrorKind::Unsupported, "mmap unavailable on this platform"))
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn bytes(&self) -> &[u8] {
        if self.ptr.is_null() || self.len == 0 {
            return &[];
        }
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl Drop for Mapping {
    fn drop(&mut self) {
        #[cfg(unix)]
        if !self.ptr.is_null() && self.len > 0 {
            unsafe {
                sys::munmap(self.ptr as *mut u8, self.len);
            }
        }
    }
}

/// A view of `floats` consecutive f32 values inside a [`Mapping`], starting
/// at byte `offset`. Cloning is cheap (an `Arc` bump); the underlying pages
/// stay mapped as long as any ref is alive.
///
/// Only valid on little-endian hosts over 4-byte-aligned offsets — the v2
/// segment writer 64-byte-aligns the embedding slab and the durable layer
/// refuses to build mapped views on big-endian targets, so both invariants
/// hold by construction.
#[derive(Clone)]
pub struct SlabRef {
    map: Arc<Mapping>,
    offset: usize,
    floats: usize,
}

impl SlabRef {
    /// Build a view, validating bounds and alignment. Returns `None` if the
    /// described range does not fit the mapping or is misaligned.
    pub fn new(map: Arc<Mapping>, offset: usize, floats: usize) -> Option<SlabRef> {
        let bytes = floats.checked_mul(4)?;
        let end = offset.checked_add(bytes)?;
        if end > map.len() || offset % std::mem::align_of::<f32>() != 0 {
            return None;
        }
        if cfg!(target_endian = "big") {
            // The slab stores raw little-endian bit patterns; a byte-order
            // mismatch must go through the decoding fallback instead.
            return None;
        }
        Some(SlabRef { map, offset, floats })
    }

    pub fn len(&self) -> usize {
        self.floats
    }

    pub fn is_empty(&self) -> bool {
        self.floats == 0
    }

    pub fn as_f32s(&self) -> &[f32] {
        if self.floats == 0 {
            return &[];
        }
        let base = self.map.bytes().as_ptr();
        debug_assert!(self.offset + self.floats * 4 <= self.map.len());
        unsafe {
            let ptr = base.add(self.offset) as *const f32;
            debug_assert_eq!(ptr as usize % std::mem::align_of::<f32>(), 0);
            std::slice::from_raw_parts(ptr, self.floats)
        }
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp_file(tag: &str, bytes: &[u8]) -> std::path::PathBuf {
        let path = std::env::temp_dir().join(format!("eagle-mmap-{tag}-{}", std::process::id()));
        let mut f = File::create(&path).unwrap();
        f.write_all(bytes).unwrap();
        f.sync_all().unwrap();
        path
    }

    #[test]
    fn maps_file_bytes_readonly() {
        let path = tmp_file("bytes", b"hello mapping");
        let map = Mapping::map(&File::open(&path).unwrap()).unwrap();
        assert_eq!(map.bytes(), b"hello mapping");
        assert_eq!(map.len(), 13);
        std::fs::remove_file(&path).unwrap();
        // POSIX: the mapping survives the unlink.
        assert_eq!(map.bytes(), b"hello mapping");
    }

    #[test]
    fn empty_file_maps_to_empty_view() {
        let path = tmp_file("empty", b"");
        let map = Mapping::map(&File::open(&path).unwrap()).unwrap();
        assert!(map.is_empty());
        assert_eq!(map.bytes(), b"" as &[u8]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn slab_ref_views_aligned_f32_runs() {
        let vals: Vec<f32> = (0..32).map(|i| i as f32 * 0.5).collect();
        let mut bytes = vec![0u8; 64];
        for v in &vals {
            bytes.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        let path = tmp_file("slab", &bytes);
        let map = Arc::new(Mapping::map(&File::open(&path).unwrap()).unwrap());
        let slab = SlabRef::new(Arc::clone(&map), 64, vals.len()).unwrap();
        assert_eq!(slab.as_f32s(), &vals[..]);
        // Out-of-bounds and misaligned views are refused.
        assert!(SlabRef::new(Arc::clone(&map), 64, vals.len() + 1).is_none());
        assert!(SlabRef::new(map, 63, 1).is_none());
        std::fs::remove_file(&path).unwrap();
    }
}
