//! Serving metrics: counters and log-bucketed latency histograms.
//!
//! Lock-free on the hot path (atomics only); snapshots are consistent
//! enough for reporting. The histogram uses power-of-two-ish log buckets
//! (HdrHistogram-style, 4 sub-buckets per octave) over microseconds.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of histogram buckets: covers ~1us to ~1.2e9 us (20 min).
const N_BUCKETS: usize = 128;
const SUB_BUCKETS_LOG2: u32 = 2; // 4 sub-buckets per octave

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Counter { value: AtomicU64::new(0) }
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Log-bucketed latency histogram over microsecond samples.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_of(us: u64) -> usize {
        // log-bucket with 2^SUB_BUCKETS_LOG2 sub-buckets per octave
        let v = us.max(1);
        let octave = 63 - v.leading_zeros();
        let sub = if octave >= SUB_BUCKETS_LOG2 {
            ((v >> (octave - SUB_BUCKETS_LOG2)) & ((1 << SUB_BUCKETS_LOG2) - 1)) as usize
        } else {
            0
        };
        (((octave as usize) << SUB_BUCKETS_LOG2) + sub).min(N_BUCKETS - 1)
    }

    /// Representative (upper-bound) value of a bucket, in microseconds.
    fn bucket_value(idx: usize) -> u64 {
        let octave = (idx >> SUB_BUCKETS_LOG2) as u32;
        let sub = (idx & ((1 << SUB_BUCKETS_LOG2) - 1)) as u64;
        if octave < SUB_BUCKETS_LOG2 {
            return 1u64 << octave;
        }
        let base = 1u64 << octave;
        base + ((sub + 1) * (base >> SUB_BUCKETS_LOG2)) - 1
    }

    /// Record one latency sample in microseconds.
    pub fn record_us(&self, us: u64) {
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Record a `Duration`.
    pub fn record(&self, d: std::time::Duration) {
        self.record_us(d.as_micros() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile (0.0..=1.0) from the bucket histogram.
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0)) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_value(i).min(self.max_us());
            }
        }
        self.max_us()
    }

    /// Human-readable one-line summary.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1}us p50={}us p90={}us p99={}us max={}us",
            self.count(),
            self.mean_us(),
            self.quantile_us(0.50),
            self.quantile_us(0.90),
            self.quantile_us(0.99),
            self.max_us(),
        )
    }
}

/// Metrics registry for the serving stack.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: Counter,
    pub feedback: Counter,
    pub embed_batches: Counter,
    pub embed_queries: Counter,
    pub route_latency: Histogram,
    pub embed_latency: Histogram,
    pub search_latency: Histogram,
    pub errors: Counter,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Multi-line report for logs / the stats endpoint.
    pub fn report(&self) -> String {
        format!(
            "requests={} feedback={} errors={}\n\
             embed: batches={} queries={} avg_batch={:.2}\n\
             route_latency: {}\n\
             embed_latency: {}\n\
             search_latency: {}",
            self.requests.get(),
            self.feedback.get(),
            self.errors.get(),
            self.embed_batches.get(),
            self.embed_queries.get(),
            self.embed_queries.get() as f64 / self.embed_batches.get().max(1) as f64,
            self.route_latency.summary(),
            self.embed_latency.summary(),
            self.search_latency.summary(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.quantile_us(0.99), 0);
    }

    #[test]
    fn histogram_single_sample() {
        let h = Histogram::new();
        h.record_us(100);
        assert_eq!(h.count(), 1);
        assert_eq!(h.mean_us(), 100.0);
        assert_eq!(h.max_us(), 100);
        // quantile is bucket-quantized but capped at max
        assert!(h.quantile_us(0.5) <= 100);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::new();
        for i in 1..=10_000u64 {
            h.record_us(i);
        }
        let p50 = h.quantile_us(0.5);
        let p90 = h.quantile_us(0.9);
        let p99 = h.quantile_us(0.99);
        assert!(p50 <= p90 && p90 <= p99);
        // log-buckets: p50 within a factor of ~1.35 of the true median
        assert!((3500..=7000).contains(&p50), "p50={p50}");
        assert!(p99 <= h.max_us());
    }

    #[test]
    fn bucket_of_monotone() {
        let mut prev = 0;
        for us in [1u64, 2, 3, 5, 10, 100, 1_000, 65_536, 1_000_000] {
            let b = Histogram::bucket_of(us);
            assert!(b >= prev, "bucket({us}) = {b} < {prev}");
            prev = b;
        }
    }

    #[test]
    fn bucket_value_covers_bucket_of() {
        for us in [1u64, 7, 63, 64, 65, 999, 123_456] {
            let idx = Histogram::bucket_of(us);
            assert!(Histogram::bucket_value(idx) >= us, "us={us} idx={idx}");
        }
    }

    #[test]
    fn zero_latency_recorded() {
        let h = Histogram::new();
        h.record_us(0);
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn metrics_report_contains_sections() {
        let m = Metrics::new();
        m.requests.inc();
        m.route_latency.record_us(42);
        let r = m.report();
        assert!(r.contains("requests=1"));
        assert!(r.contains("route_latency"));
    }

    #[test]
    fn histogram_concurrent_recording() {
        use std::sync::Arc;
        let h = Arc::new(Histogram::new());
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        h.record_us(t * 1000 + i);
                    }
                })
            })
            .collect();
        for j in handles {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
    }
}
