//! Command-line interface (clap is unavailable offline; hand-rolled).
//!
//! ```text
//! eagle serve   [--addr A] [--workers N] [--snapshot FILE] [--config FILE] [--set k=v]...
//! eagle eval    [--per-dataset N] [--dataset NAME|all] [--routers eagle,knn,mlp,svm] [--seed S]
//! eagle gen-data --out FILE [--per-dataset N] [--seed S]
//! eagle info
//! ```

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::baselines::knn::KnnPredictor;
use crate::baselines::mlp::{MlpOptions, MlpPredictor};
use crate::baselines::svm::{SvmOptions, SvmPredictor};
use crate::baselines::QualityPredictor;
use crate::bench::{fmt, print_table};
use crate::config::{env_override, Config, Role};
use crate::coordinator::registry::ModelRegistry;
use crate::coordinator::router::EagleRouter;
use crate::coordinator::PredictorRouter;
use crate::eval::harness::{bench_data_params, EmbedderRig, Experiment};
use crate::json::{self, Value};
use crate::metrics::Metrics;
use crate::routerbench::{gen, DATASETS};
use crate::vectordb::flat::FlatStore;
use crate::vectordb::ReadIndex;

/// Simple flag parser: `--key value` pairs plus repeated `--set k=v`.
pub struct Args {
    pub positional: Vec<String>,
    flags: Vec<(String, String)>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = argv
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("flag --{key} needs a value"))?;
                flags.push((key.to_string(), val.clone()));
                i += 2;
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Args { positional, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags.iter().filter(|(k, _)| k == key).map(|(_, v)| v.as_str()).collect()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}: not an integer")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}: not an integer")),
        }
    }
}

const USAGE: &str = "\
eagle — training-free multi-LLM router (reproduction of Zhao et al. 2024)

USAGE:
  eagle serve    [--addr HOST:PORT] [--workers N] [--snapshot FILE]
                 [--snapshot-out FILE] [--max-connections N] [--max-inflight N]
                 [--idle-timeout-ms MS] [--role leader|follower]
                 [--config FILE] [--set key=value]...
  eagle eval     [--per-dataset N] [--dataset NAME|all]
                 [--routers eagle,eagle-global,eagle-local,knn,mlp,svm]
                 [--seed S] [--config FILE]
  eagle scenarios [--seed S] [--per-dataset N] [--out DIR] [--config FILE]
  eagle gen-data --out FILE [--per-dataset N] [--seed S]
  eagle info     [--config FILE]
  eagle help

The server's default routing policy comes from the [policy] config
section (policy.mode = budget | cost_aware | threshold); v2 clients can
override it per query.
";

/// Entry point; returns the process exit code.
pub fn run(argv: &[String]) -> Result<i32> {
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(2);
    };
    let args = Args::parse(&argv[1..])?;
    let cfg = load_config(&args)?;
    match cmd.as_str() {
        "serve" => cmd_serve(&args, &cfg),
        "eval" => cmd_eval(&args, &cfg),
        "scenarios" => cmd_scenarios(&args, &cfg),
        "gen-data" => cmd_gen_data(&args, &cfg),
        "info" => cmd_info(&cfg),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(0)
        }
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            Ok(2)
        }
    }
}

fn load_config(args: &Args) -> Result<Config> {
    let overrides: Vec<(String, String)> = args
        .get_all("set")
        .iter()
        .map(|kv| {
            kv.split_once('=')
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .ok_or_else(|| anyhow!("--set expects key=value, got '{kv}'"))
        })
        .collect::<Result<_>>()?;
    let path = args.get("config").map(Path::new);
    Config::load(path, &overrides).map_err(|e| anyhow!("{e}"))
}

fn cmd_info(cfg: &Config) -> Result<i32> {
    println!("eagle configuration:");
    println!("  eagle: P={} N={} K={}", cfg.eagle.p, cfg.eagle.n_neighbors, cfg.eagle.k_factor);
    println!(
        "  epoch: publish_every={} publish_interval_ms={}",
        cfg.epoch.publish_every, cfg.epoch.publish_interval_ms
    );
    println!(
        "  shards: count={} hash_seed={:#x}",
        cfg.shards.count, cfg.shards.hash_seed
    );
    let n_cells = if cfg.ivf.n_cells == 0 {
        "auto (sqrt(corpus) at rebuild)".to_string()
    } else {
        cfg.ivf.n_cells.to_string()
    };
    println!(
        "  ivf: publish_threshold={} n_cells={} nprobe={}",
        cfg.ivf.publish_threshold, n_cells, cfg.ivf.nprobe
    );
    println!(
        "  quant: mode={} rerank_factor={} (EAGLE_QUANT overrides)",
        if cfg.quant.enable { "sq8" } else { "off" },
        cfg.quant.rerank_factor
    );
    println!(
        "  persist: interval_ms={} dir={} seal_bytes={} fsync={} mmap={} \
         compact_interval_ms={} gc_grace_ms={} path={}",
        cfg.persist.interval_ms,
        if cfg.persist.dir.is_empty() { "<off>" } else { &cfg.persist.dir },
        cfg.persist.seal_bytes,
        cfg.persist.fsync,
        cfg.persist.mmap,
        cfg.persist.compact_interval_ms,
        cfg.persist.gc_grace_ms,
        if cfg.persist.path.is_empty() { "<snapshot-out>" } else { &cfg.persist.path }
    );
    println!(
        "  server: addr={} workers={} max_connections={} max_inflight={} idle_timeout_ms={}",
        cfg.server.addr,
        cfg.server.workers,
        cfg.server.max_connections,
        cfg.server.max_inflight,
        cfg.server.idle_timeout_ms
    );
    println!(
        "  kernel: backend={} (host detects {}; EAGLE_KERNEL overrides)",
        cfg.kernel.backend,
        crate::vectordb::kernel::detect().name()
    );
    println!(
        "  replica: role={} poll_ms={} backoff_max_ms={} (EAGLE_ROLE and --role override)",
        cfg.replica.role, cfg.replica.poll_ms, cfg.replica.backoff_max_ms
    );
    println!("  artifacts: {}", cfg.embed.artifacts_dir);
    match crate::runtime::Manifest::load(Path::new(&cfg.embed.artifacts_dir)) {
        Ok(m) => println!(
            "  manifest: d_model={} seq_len={} buckets={:?} (run `make artifacts` to rebuild)",
            m.model.d_model, m.model.seq_len, m.embed_batch_sizes
        ),
        Err(e) => println!("  manifest: unavailable ({e})"),
    }
    let registry = ModelRegistry::routerbench();
    let mut rows = vec![vec!["model".to_string(), "$/query (expected)".to_string()]];
    for e in registry.entries() {
        rows.push(vec![e.name.clone(), format!("{:.6}", e.expected_cost)]);
    }
    print_table("model pool", &rows);
    Ok(0)
}

fn cmd_gen_data(args: &Args, cfg: &Config) -> Result<i32> {
    let out = args.get("out").ok_or_else(|| anyhow!("gen-data requires --out FILE"))?;
    let mut params = cfg.data.clone();
    params.per_dataset = args.usize_or("per-dataset", params.per_dataset)?;
    params.seed = args.u64_or("seed", params.seed)?;
    let benchmark = gen::generate(&params);

    // serialize: per split, samples + feedback
    let splits: Vec<Value> = benchmark
        .splits
        .iter()
        .map(|s| {
            let samples: Vec<Value> = s
                .train
                .iter()
                .chain(&s.test)
                .map(|x| {
                    json::obj(vec![
                        ("text", json::str_v(&x.text)),
                        ("topic", json::num(x.topic as f64)),
                        ("quality", json::f32_arr(&x.quality)),
                        ("cost", json::f32_arr(&x.cost)),
                    ])
                })
                .collect();
            json::obj(vec![
                ("dataset", json::str_v(DATASETS[s.dataset])),
                ("n_train", json::num(s.train.len() as f64)),
                ("n_test", json::num(s.test.len() as f64)),
                ("samples", Value::Arr(samples)),
                (
                    "feedback",
                    Value::Arr(
                        s.feedback
                            .iter()
                            .map(|f| {
                                json::obj(vec![
                                    ("sample", json::num(f.sample as f64)),
                                    ("a", json::num(f.comparison.a as f64)),
                                    ("b", json::num(f.comparison.b as f64)),
                                    ("s", json::num(f.comparison.outcome.encode())),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();
    let doc = json::obj(vec![
        ("seed", json::num(params.seed as f64)),
        ("per_dataset", json::num(params.per_dataset as f64)),
        ("models", Value::Arr(
            crate::routerbench::models::MODELS
                .iter()
                .map(|m| json::str_v(m.name))
                .collect(),
        )),
        ("splits", Value::Arr(splits)),
    ]);
    std::fs::write(out, doc.to_json()).with_context(|| format!("writing {out}"))?;
    println!("wrote {out} ({} datasets x {} prompts)", DATASETS.len(), params.per_dataset);
    Ok(0)
}

fn cmd_eval(args: &Args, cfg: &Config) -> Result<i32> {
    let per_dataset = args.usize_or("per-dataset", 600)?;
    let seed = args.u64_or("seed", cfg.data.seed)?;
    let routers_arg = args.get("routers").unwrap_or("eagle,knn,mlp,svm");
    let dataset_arg = args.get("dataset").unwrap_or("all");

    let rig = EmbedderRig::auto(Path::new(&cfg.embed.artifacts_dir));
    println!(
        "embedder: {}",
        if rig.is_pjrt { "PJRT (AOT artifacts)" } else { "hash fallback" }
    );
    let exp = Experiment::build(&bench_data_params(seed, per_dataset), &rig);

    let splits: Vec<usize> = if dataset_arg == "all" {
        (0..DATASETS.len()).collect()
    } else {
        vec![DATASETS
            .iter()
            .position(|d| *d == dataset_arg)
            .ok_or_else(|| anyhow!("unknown dataset '{dataset_arg}'"))?]
    };

    let mut rows = vec![{
        let mut h = vec!["router".to_string()];
        h.extend(splits.iter().map(|&s| DATASETS[s].to_string()));
        h.push("sum".to_string());
        h
    }];

    for rname in routers_arg.split(',') {
        let mut row = vec![rname.to_string()];
        let mut sum = 0.0;
        for &si in &splits {
            let auc = eval_one(&exp, cfg, rname, si)?;
            sum += auc;
            row.push(fmt(auc, 4));
        }
        row.push(fmt(sum, 4));
        rows.push(row);
    }
    print_table(&format!("AUC (per-dataset={per_dataset}, seed={seed})"), &rows);
    Ok(0)
}

/// Fit + evaluate one router by name on one split; returns AUC.
pub fn eval_one(exp: &Experiment, cfg: &Config, name: &str, split: usize) -> Result<f64> {
    let auc = match name {
        "eagle" | "eagle-global" | "eagle-local" => {
            let mut params = cfg.eagle.clone();
            params.p = match name {
                "eagle-global" => 1.0,
                "eagle-local" => 0.0,
                _ => params.p,
            };
            let router = exp.fit_eagle(split, params, 1.0);
            exp.eval(&router, split).auc()
        }
        "knn" => {
            let mut p = KnnPredictor::new(cfg.baselines.knn_neighbors);
            p.fit(&exp.train_set_feedback(split, 1.0));
            exp.eval(&PredictorRouter::new(p), split).auc()
        }
        "mlp" => {
            let mut p = MlpPredictor::new(MlpOptions {
                hidden: cfg.baselines.mlp_hidden,
                epochs: cfg.baselines.mlp_epochs,
                lr: cfg.baselines.mlp_lr,
                ..Default::default()
            });
            p.fit(&exp.train_set_feedback(split, 1.0));
            exp.eval(&PredictorRouter::new(p), split).auc()
        }
        "svm" => {
            let mut p = SvmPredictor::new(SvmOptions {
                epsilon: cfg.baselines.svm_epsilon,
                epochs: cfg.baselines.svm_epochs,
                lr: cfg.baselines.svm_lr,
                ..Default::default()
            });
            p.fit(&exp.train_set_feedback(split, 1.0));
            exp.eval(&PredictorRouter::new(p), split).auc()
        }
        "oracle" => {
            crate::eval::oracle_curve(
                &exp.split(split).test,
                &exp.policy,
                DATASETS[exp.split(split).dataset],
            )
            .auc()
        }
        other => bail!("unknown router '{other}'"),
    };
    Ok(auc)
}

fn cmd_serve(args: &Args, cfg: &Config) -> Result<i32> {
    use std::sync::Arc;

    let addr = args.get("addr").unwrap_or(&cfg.server.addr).to_string();
    let workers = args.usize_or("workers", cfg.server.workers)?;
    // role precedence: --role, then EAGLE_ROLE, then [replica] role
    let cfg_role = Role::parse(&cfg.replica.role).map_err(|e| anyhow!("replica.role: {e}"))?;
    let role = match args.get("role") {
        Some(s) => Role::parse(s).map_err(|e| anyhow!("--role {s}: {e}"))?,
        None => env_override("EAGLE_ROLE", "[replica] role", cfg_role, Role::parse),
    };
    let admission = crate::server::Admission {
        max_connections: args.usize_or("max-connections", cfg.server.max_connections)?,
        max_inflight: args.usize_or("max-inflight", cfg.server.max_inflight)?,
        idle_timeout_ms: args.u64_or("idle-timeout-ms", cfg.server.idle_timeout_ms)?,
    };
    let metrics = Arc::new(Metrics::new());

    let registry = ModelRegistry::routerbench();
    let router = match args.get("snapshot") {
        Some(path) => {
            let r = crate::coordinator::state::load_from(Path::new(path))?;
            println!("restored snapshot: {} feedback records", r.feedback_len());
            r
        }
        None => EagleRouter::new(cfg.eagle.clone(), registry.len(), FlatStore::new(256)),
    };

    let batcher = crate::embedding::BatcherOptions {
        batch_window_us: cfg.embed.batch_window_us,
        max_batch: cfg.embed.max_batch,
    };
    let service = match crate::embedding::EmbedService::start(
        Path::new(&cfg.embed.artifacts_dir),
        batcher,
        metrics.clone(),
    ) {
        Ok(s) => s,
        Err(e) => {
            println!(
                "warning: PJRT embed service unavailable ({e}); serving with the \
                 pure-rust hash embedder (dev/e2e quality, NOT the paper's embedder)"
            );
            crate::embedding::EmbedService::start_hash(
                router.store().dim(),
                batcher,
                metrics.clone(),
            )
        }
    };

    // durable segment store ([persist] dir) is the only background
    // persistence mode; persist.path survives as a deprecated alias for
    // the admin snapshot op's JSON target (--snapshot-out)
    let mut snapshot_out = args.get("snapshot-out").map(std::path::PathBuf::from);
    if !cfg.persist.path.is_empty() {
        println!(
            "warning: persist.path is deprecated — it now only names the admin \
             snapshot op's JSON target (like --snapshot-out); use [persist] dir \
             for background persistence"
        );
        if snapshot_out.is_none() {
            snapshot_out = Some(std::path::PathBuf::from(&cfg.persist.path));
        }
    }
    let persist_dir = (!cfg.persist.dir.is_empty())
        .then(|| std::path::PathBuf::from(&cfg.persist.dir));
    if role == Role::Follower && persist_dir.is_none() {
        bail!(
            "--role follower requires [persist] dir (the leader's durable store \
             to tail); set persist.dir"
        );
    }
    match &persist_dir {
        Some(dir) if role == Role::Follower => {
            // The leader owns the store; all we need is for it to exist.
            // Tolerate a short startup race (follower launched first).
            if !crate::coordinator::durable::DurableStore::exists(dir) {
                println!("follower: waiting for the leader's store at {} ...", dir.display());
                let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
                while !crate::coordinator::durable::DurableStore::exists(dir) {
                    if std::time::Instant::now() >= deadline {
                        bail!(
                            "follower: no durable store at {} after 10s (is the \
                             leader running with persist.dir set?)",
                            dir.display()
                        );
                    }
                    std::thread::sleep(std::time::Duration::from_millis(100));
                }
            }
            println!(
                "follower: tailing the leader's store at {} (poll every {} ms; \
                 feedback/snapshot redirect to the leader until promote)",
                dir.display(),
                cfg.replica.poll_ms
            );
        }
        Some(dir) => {
            if crate::coordinator::durable::DurableStore::exists(dir) {
                println!(
                    "durable store at {} exists: recovering (snapshot/cold-start state \
                     is superseded by the recovered corpus)",
                    dir.display()
                );
            } else {
                println!(
                    "durable store at {}: bootstrapping from the starting router \
                     ({} records)",
                    dir.display(),
                    router.feedback_len()
                );
            }
            println!(
                "segment-granular persistence: seal_bytes={} fsync={} mmap={} \
                 checkpoint beat={} compaction={}",
                cfg.persist.seal_bytes,
                cfg.persist.fsync,
                cfg.persist.mmap,
                if cfg.persist.interval_ms == 0 {
                    "flush/admin/shutdown only".to_string()
                } else {
                    format!("every {} ms", cfg.persist.interval_ms)
                },
                if cfg.persist.compact_interval_ms == 0 {
                    "off".to_string()
                } else {
                    format!(
                        "every {} ms (gc grace {} ms)",
                        cfg.persist.compact_interval_ms, cfg.persist.gc_grace_ms
                    )
                },
            );
        }
        None if cfg.persist.interval_ms > 0 => println!(
            "warning: persist.interval_ms set but no persist.dir; the periodic \
             checkpoint beat only applies to the durable segment store"
        ),
        None => {}
    }

    let default_policy = cfg.policy.spec().map_err(|e| anyhow!("policy: {e}"))?;
    println!(
        "default routing policy: {} (v2 clients can override per query)",
        default_policy.mode()
    );

    let mut builder = crate::server::ServerState::builder(
        router,
        registry,
        service.handle(),
        metrics,
    )
    .options(crate::server::ServerOptions {
        epoch: cfg.epoch.clone(),
        shards: cfg.shards.clone(),
        ivf: cfg.ivf.clone(),
        quant: cfg.quant,
        persist_interval_ms: cfg.persist.interval_ms,
        persist_dir: persist_dir.clone(),
        seal_bytes: cfg.persist.seal_bytes,
        fsync: cfg.persist.fsync,
        mmap: cfg.persist.mmap,
        compact_interval_ms: cfg.persist.compact_interval_ms,
        gc_grace_ms: cfg.persist.gc_grace_ms,
        kernel_backend: cfg.kernel.backend.clone(),
        admission: admission.clone(),
        role,
        replica_poll_ms: cfg.replica.poll_ms,
        replica_backoff_max_ms: cfg.replica.backoff_max_ms,
    })
    .default_policy(default_policy);
    if let Some(out) = snapshot_out {
        if persist_dir.is_some() {
            println!(
                "note: --snapshot-out {} is ignored while [persist] dir is set — the \
                 admin snapshot op checkpoints the durable store instead",
                out.display()
            );
        } else {
            println!("admin snapshot op enabled -> {}", out.display());
            builder = builder.snapshot_path(out);
        }
    }
    let state = builder.build();
    println!(
        "scoring kernel: {} (configured '{}'; EAGLE_KERNEL overrides)",
        crate::vectordb::kernel::active().name(),
        cfg.kernel.backend
    );
    println!(
        "corpus scan: {} (EAGLE_QUANT overrides), ivf n_cells: {}",
        if cfg.quant.enable {
            format!("sq8 + exact rerank x{}", cfg.quant.rerank_factor)
        } else {
            "exact f32".to_string()
        },
        if cfg.ivf.n_cells == 0 {
            "auto (sqrt(corpus) at rebuild)".to_string()
        } else {
            cfg.ivf.n_cells.to_string()
        },
    );
    if let Some(store) = state.durable_store() {
        println!(
            "durable corpus ready: {} records ({} sealed segment file(s)) at {}",
            state.snapshots.load().store_len(),
            store.segment_counts().iter().sum::<usize>(),
            store.dir().display()
        );
        // the on-disk partition is physical: a recovered store keeps its
        // own topology and params, whatever the config now says
        let meta = store.meta();
        if meta.shards != cfg.shards {
            println!(
                "warning: [shards] config (count={} seed={:#x}) differs from the durable \
                 store's (count={} seed={:#x}); the store's topology is in effect — \
                 re-shard by bootstrapping a fresh persist.dir from a snapshot",
                cfg.shards.count,
                cfg.shards.hash_seed,
                meta.shards.count,
                meta.shards.hash_seed,
            );
        }
        if meta.params != cfg.eagle {
            println!(
                "warning: [eagle] config differs from the durable store's params \
                 (P={} N={} K={}); the store's params are in effect",
                meta.params.p, meta.params.n_neighbors, meta.params.k_factor,
            );
        }
    }
    let state = Arc::new(state);
    let server = crate::server::Server::start(state, &addr, workers)?;
    println!(
        "eagle serving on {} (event loop + {} exec workers, {} shard(s) with one \
         applier each, epoch cadence: every {} records / {} ms, ivf publish \
         threshold: {}); Ctrl-C to stop",
        server.addr,
        workers,
        cfg.shards.count,
        cfg.epoch.publish_every,
        cfg.epoch.publish_interval_ms,
        if cfg.ivf.publish_threshold == 0 {
            "off".to_string()
        } else {
            format!("{} entries/shard", cfg.ivf.publish_threshold)
        },
    );
    println!(
        "admission: max_connections={} max_inflight={} idle_timeout={} \
         (load sheds are counted per reason; read them via the stats op)",
        admission.max_connections,
        admission.max_inflight,
        if admission.idle_timeout_ms == 0 {
            "off".to_string()
        } else {
            format!("{} ms", admission.idle_timeout_ms)
        },
    );

    // Block forever (Ctrl-C kills the process; state can be snapshotted
    // via an admin op in a future protocol revision).
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// `eagle scenarios`: run the deterministic scenario matrix and write
/// the CSV/JSON artifacts.
fn cmd_scenarios(args: &Args, cfg: &Config) -> Result<i32> {
    use crate::eval::scenario::{run_matrix, ScenarioConfig, METHODS, SCENARIOS};

    let defaults = ScenarioConfig::smoke();
    let scenario_cfg = ScenarioConfig {
        seed: args.u64_or("seed", cfg.data.seed)?,
        per_dataset: args.usize_or("per-dataset", defaults.per_dataset)?,
    };
    println!(
        "scenario matrix: seed={} per_dataset={} ({} scenarios x {} methods)",
        scenario_cfg.seed,
        scenario_cfg.per_dataset,
        SCENARIOS.len(),
        METHODS.len()
    );
    let result = run_matrix(&scenario_cfg);

    let mut rows = vec![{
        let mut h = vec!["method".to_string()];
        h.extend(SCENARIOS.iter().filter(|s| **s != "adversarial").map(|s| s.to_string()));
        h
    }];
    for method in METHODS {
        let mut row = vec![method.to_string()];
        for scenario in SCENARIOS.iter().filter(|s| **s != "adversarial") {
            row.push(fmt(result.get(scenario, method, "auc").unwrap_or(f64::NAN), 4));
        }
        rows.push(row);
    }
    print_table("Scenario AUC by method", &rows);

    let mut diag = vec![vec!["diagnostic".to_string(), "value".to_string()]];
    for (s, m, k) in [
        ("drift", "budget", "adaptation_gain"),
        ("cold_start", "budget", "recovery_gain"),
        ("burst_skew", "sharded", "score_divergence"),
        ("adversarial", "wire", "error_reply_rate"),
        ("adversarial", "durable", "recovered_ratio"),
    ] {
        diag.push(vec![
            format!("{s}.{m}.{k}"),
            fmt(result.get(s, m, k).unwrap_or(f64::NAN), 4),
        ]);
    }
    print_table("Scenario diagnostics", &diag);

    let out = std::path::PathBuf::from(args.get("out").unwrap_or("."));
    let (csv, jsonp) = result
        .write_to(&out)
        .with_context(|| format!("writing scenario artifacts into {}", out.display()))?;
    println!("wrote {} and {}", csv.display(), jsonp.display());
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn args_parse_flags_and_positional() {
        let a = Args::parse(&argv(&["--x", "1", "pos", "--set", "a=b", "--set", "c=d"])).unwrap();
        assert_eq!(a.get("x"), Some("1"));
        assert_eq!(a.positional, vec!["pos"]);
        assert_eq!(a.get_all("set"), vec!["a=b", "c=d"]);
    }

    #[test]
    fn args_missing_value_errors() {
        assert!(Args::parse(&argv(&["--x"])).is_err());
    }

    #[test]
    fn run_help() {
        assert_eq!(run(&argv(&["help"])).unwrap(), 0);
        assert_eq!(run(&[]).unwrap(), 2);
        assert_eq!(run(&argv(&["bogus"])).unwrap(), 2);
    }

    #[test]
    fn config_overrides_via_set() {
        let a = Args::parse(&argv(&["--set", "eagle.p=0.25"])).unwrap();
        let cfg = load_config(&a).unwrap();
        assert_eq!(cfg.eagle.p, 0.25);
    }

    #[test]
    fn bad_set_syntax_errors() {
        let a = Args::parse(&argv(&["--set", "nonsense"])).unwrap();
        assert!(load_config(&a).is_err());
    }

    #[test]
    fn gen_data_writes_file() {
        let a = Args::parse(&argv(&[
            "--out",
            "/tmp/eagle_cli_gen_test.json",
            "--per-dataset",
            "20",
        ]))
        .unwrap();
        let cfg = Config::default();
        assert_eq!(cmd_gen_data(&a, &cfg).unwrap(), 0);
        let text = std::fs::read_to_string("/tmp/eagle_cli_gen_test.json").unwrap();
        let v = json::parse(&text).unwrap();
        assert_eq!(v.get("splits").as_arr().unwrap().len(), 7);
        std::fs::remove_file("/tmp/eagle_cli_gen_test.json").ok();
    }
}
