//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Used by every target under `rust/benches/` (`harness = false`):
//! warmup, timed iterations, mean/p50/p99, plus simple aligned-table
//! printing for the figure/table reproductions.

use std::time::Instant;

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn per_second(&self) -> f64 {
        if self.mean_ns == 0.0 {
            0.0
        } else {
            1e9 / self.mean_ns
        }
    }

    /// One formatted line, criterion-style.
    pub fn line(&self) -> String {
        format!(
            "{:<38} {:>10.2} us/iter  p50 {:>9.2} us  p99 {:>9.2} us  ({:.0}/s, {} iters)",
            self.name,
            self.mean_ns / 1e3,
            self.p50_ns / 1e3,
            self.p99_ns / 1e3,
            self.per_second(),
            self.iters
        )
    }
}

/// Time `f` adaptively: warm up, then run enough iterations to fill
/// ~`target_ms` of wall clock (min 10 iters), and report percentiles.
pub fn bench<F: FnMut()>(name: &str, target_ms: u64, mut f: F) -> BenchResult {
    // warmup: 3 runs or 50ms, whichever first
    let warm_start = Instant::now();
    for _ in 0..3 {
        f();
        if warm_start.elapsed().as_millis() > 50 {
            break;
        }
    }
    // estimate per-iter cost
    let t0 = Instant::now();
    f();
    let est = t0.elapsed().as_nanos().max(1) as u64;
    let target_ns = target_ms.saturating_mul(1_000_000);
    let iters = (target_ns / est).clamp(10, 100_000);

    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p = |q: f64| samples[((q * (samples.len() - 1) as f64) as usize).min(samples.len() - 1)];
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: p(0.50),
        p99_ns: p(0.99),
    }
}

/// True when benches should run in CI smoke mode (`EAGLE_BENCH_SMOKE=1`):
/// capped iteration targets and shortened measurement windows, so the
/// full bench suite finishes in seconds and still emits every metric.
pub fn smoke() -> bool {
    std::env::var("EAGLE_BENCH_SMOKE").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

/// True when benches should write `BENCH_<name>.json` result files
/// (`EAGLE_BENCH_JSON=1`, or implied by smoke mode so CI always gets its
/// artifact).
pub fn json_enabled() -> bool {
    std::env::var("EAGLE_BENCH_JSON").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
        || smoke()
}

/// Flat machine-readable bench report: metric name -> value. Written as
/// `BENCH_<name>.json` (into `EAGLE_BENCH_JSON_DIR`, default the current
/// directory) so CI can upload the perf trajectory per PR as an artifact.
pub struct JsonReport {
    name: String,
    metrics: Vec<(String, f64)>,
}

impl JsonReport {
    pub fn new(name: &str) -> Self {
        JsonReport { name: name.to_string(), metrics: Vec::new() }
    }

    /// Record one scalar metric.
    pub fn push(&mut self, key: &str, value: f64) {
        self.metrics.push((key.to_string(), value));
    }

    /// Record a [`BenchResult`]'s mean/p50/p99 (microseconds).
    pub fn push_result(&mut self, r: &BenchResult) {
        self.push(&format!("{}.mean_us", r.name), r.mean_ns / 1e3);
        self.push(&format!("{}.p50_us", r.name), r.p50_ns / 1e3);
        self.push(&format!("{}.p99_us", r.name), r.p99_ns / 1e3);
    }

    /// Write `BENCH_<name>.json` into `EAGLE_BENCH_JSON_DIR` (default the
    /// current directory); returns the path written.
    pub fn write(&self) -> std::io::Result<std::path::PathBuf> {
        let dir = std::env::var("EAGLE_BENCH_JSON_DIR").unwrap_or_else(|_| ".".to_string());
        self.write_to(std::path::Path::new(&dir))
    }

    /// Write `BENCH_<name>.json` into an explicit directory.
    pub fn write_to(&self, dir: &std::path::Path) -> std::io::Result<std::path::PathBuf> {
        use crate::json::{self, Value};
        let path = dir.join(format!("BENCH_{}.json", self.name));
        let doc = json::obj(vec![
            ("bench", json::str_v(&self.name)),
            ("smoke", json::num(f64::from(u8::from(smoke())))),
            (
                "metrics",
                Value::Arr(
                    self.metrics
                        .iter()
                        .map(|(k, v)| {
                            json::obj(vec![("name", json::str_v(k)), ("value", json::num(*v))])
                        })
                        .collect(),
                ),
            ),
        ]);
        std::fs::write(&path, doc.to_json())?;
        Ok(path)
    }
}

/// Time a single run of `f` in seconds (for table-style results where the
/// operation itself is the measurement, e.g. training time).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Print an aligned table (first row = header).
pub fn print_table(title: &str, rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    if rows.is_empty() {
        return;
    }
    let cols = rows.iter().map(|r| r.len()).max().unwrap();
    let mut widths = vec![0usize; cols];
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    for (ri, row) in rows.iter().enumerate() {
        let line: Vec<String> = row
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
            .collect();
        println!("  {}", line.join("  "));
        if ri == 0 {
            let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
            println!("  {}", sep.join("  "));
        }
    }
}

/// Format a float with fixed decimals (table cells).
pub fn fmt(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut count = 0u64;
        let r = bench("noop", 5, || {
            count += 1;
            std::hint::black_box(count);
        });
        // warmup (3) + estimate (1) + timed iters
        assert_eq!(count, r.iters + 4);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p50_ns <= r.p99_ns);
    }

    #[test]
    fn bench_measures_sleep_roughly() {
        let r = bench("sleep", 20, || {
            std::thread::sleep(std::time::Duration::from_micros(200));
        });
        assert!(r.mean_ns > 150_000.0, "mean = {}", r.mean_ns);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, secs) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn json_report_roundtrips_through_codec() {
        let mut report = JsonReport::new("unit_test");
        report.push("route.qps", 1234.5);
        let r = bench("noop2", 1, || {});
        report.push_result(&r);
        let dir = std::env::temp_dir().join(format!("eagle_bench_json_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = report.write_to(&dir).unwrap();
        assert_eq!(path.file_name().unwrap().to_str().unwrap(), "BENCH_unit_test.json");
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::json::parse(&text).unwrap();
        assert_eq!(v.get("bench").as_str().unwrap(), "unit_test");
        let metrics = v.get("metrics").as_arr().unwrap();
        assert_eq!(metrics.len(), 4);
        assert_eq!(metrics[0].get("name").as_str().unwrap(), "route.qps");
        assert!((metrics[0].get("value").as_f64().unwrap() - 1234.5).abs() < 1e-9);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn line_and_table_do_not_panic() {
        let r = bench("x", 1, || {});
        let _ = r.line();
        print_table(
            "t",
            &[
                vec!["a".into(), "b".into()],
                vec!["1".into(), "2.5".into()],
            ],
        );
        assert_eq!(fmt(1.234, 2), "1.23");
    }
}
