//! Configuration system: typed config structs + a minimal TOML-subset
//! loader + `key=value` CLI overrides.
//!
//! The file format is the flat-table TOML subset we need:
//!
//! ```toml
//! [eagle]
//! p = 0.5
//! n_neighbors = 20
//! k_factor = 32.0
//!
//! [server]
//! addr = "127.0.0.1:7878"
//! workers = 4
//! ```
//!
//! Every field has a default matching the paper's Appendix A, so an empty
//! config is fully usable. CLI overrides use dotted paths:
//! `--set eagle.p=0.7 --set server.workers=8`.

use std::collections::BTreeMap;
use std::path::Path;

/// Eagle router parameters (paper Appendix A.1).
#[derive(Debug, Clone, PartialEq)]
pub struct EagleParams {
    /// Global/local mixing weight P in `P*Global + (1-P)*Local`.
    pub p: f64,
    /// Local neighborhood size N.
    pub n_neighbors: usize,
    /// ELO K-factor.
    pub k_factor: f64,
}

impl Default for EagleParams {
    fn default() -> Self {
        EagleParams { p: 0.5, n_neighbors: 20, k_factor: 32.0 }
    }
}

/// Baseline router parameters (paper Appendix A.2).
#[derive(Debug, Clone, PartialEq)]
pub struct BaselineParams {
    /// Neighbor size for KNN and the similarity-weighted features.
    pub knn_neighbors: usize,
    /// MLP hidden width.
    pub mlp_hidden: usize,
    /// MLP training epochs.
    pub mlp_epochs: usize,
    /// MLP learning rate.
    pub mlp_lr: f64,
    /// SVM (LinearSVR) epsilon.
    pub svm_epsilon: f64,
    /// SVM training epochs.
    pub svm_epochs: usize,
    /// SVM learning rate.
    pub svm_lr: f64,
}

impl Default for BaselineParams {
    fn default() -> Self {
        BaselineParams {
            knn_neighbors: 40,
            mlp_hidden: 100,
            mlp_epochs: 60,
            mlp_lr: 1e-3,
            svm_epsilon: 0.0,
            svm_epochs: 40,
            svm_lr: 1e-2,
        }
    }
}

/// Embedding-service parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct EmbedParams {
    /// Directory holding the AOT artifacts (manifest.json etc.).
    pub artifacts_dir: String,
    /// Max time a request waits for batch-mates before dispatch.
    pub batch_window_us: u64,
    /// Upper bound on batch size (clamped to compiled buckets).
    pub max_batch: usize,
}

impl Default for EmbedParams {
    fn default() -> Self {
        EmbedParams {
            artifacts_dir: "artifacts".to_string(),
            batch_window_us: 200,
            max_batch: 32,
        }
    }
}

/// Serving front-end parameters: execution pool size plus the admission
/// limits the event loop enforces (see [`crate::server::Admission`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ServerParams {
    pub addr: String,
    /// Execution worker threads (connection fan-in is the event loop,
    /// so this sizes request execution, not connection capacity).
    pub workers: usize,
    /// Max simultaneously open client connections; excess connections
    /// get one load-shed error line and are closed.
    pub max_connections: usize,
    /// Max request lines executing at once across all connections;
    /// lines over the budget get a load-shed error reply.
    pub max_inflight: usize,
    /// Close connections idle for this many ms (0 = never).
    pub idle_timeout_ms: u64,
}

impl Default for ServerParams {
    fn default() -> Self {
        ServerParams {
            addr: "127.0.0.1:7878".to_string(),
            workers: 4,
            max_connections: 4096,
            max_inflight: 256,
            idle_timeout_ms: 30_000,
        }
    }
}

/// Snapshot-publication (epoch) cadence for the RCU routing core
/// ([`crate::coordinator::snapshot`]). The writer republishes the scoring
/// snapshot after `publish_every` new feedback records, or once
/// `publish_interval_ms` has elapsed with any records pending — whichever
/// trips first. Smaller values tighten feedback-to-routing latency;
/// larger values amortize publication under storms.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochParams {
    /// Publish after this many new records (K).
    pub publish_every: usize,
    /// Publish pending records no later than this many ms after the last
    /// publish (T).
    pub publish_interval_ms: u64,
}

impl Default for EpochParams {
    fn default() -> Self {
        EpochParams { publish_every: 64, publish_interval_ms: 25 }
    }
}

/// Sharding topology for scatter-gather snapshot routing
/// ([`crate::coordinator::sharded`]). The corpus is partitioned across
/// `count` shards by a deterministic hash of the embedding bits (seeded
/// by `hash_seed`); each shard gets its own writer and publication ring.
/// `count = 1` is the single-shard RCU path, scoring-identical at any
/// count.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardParams {
    /// Number of shards (1..=64).
    pub count: usize,
    /// Seed for the embedding-hash partitioner.
    pub hash_seed: u64,
}

impl Default for ShardParams {
    fn default() -> Self {
        ShardParams { count: 1, hash_seed: 0xEA61E }
    }
}

/// IVF snapshot-publication policy for the writer side
/// ([`crate::coordinator::snapshot::RouterWriter`]). Once a shard's corpus
/// reaches `publish_threshold` entries, the writer rebuilds an IVF core
/// over the full shard contents at compaction time (off the route path —
/// readers keep their pinned snapshots) and publishes
/// `SnapshotView::Ivf` (core probed at `nprobe` of `n_cells` cells +
/// an exact-scanned tail of newer entries) instead of the flat view, so
/// per-query search cost stops growing linearly with corpus size.
/// `publish_threshold = 0` disables IVF publication entirely.
#[derive(Debug, Clone, PartialEq)]
pub struct IvfPublishParams {
    /// Corpus size (per shard) beyond which snapshots publish an IVF view
    /// (0 = never).
    pub publish_threshold: usize,
    /// Number of k-means cells in the rebuilt core. `0` (spelled `auto`
    /// in config files) defers the choice to core-rebuild time, where it
    /// resolves to `sqrt(corpus)` — the classic IVF balance point between
    /// centroid-ranking cost and cell-scan cost.
    pub n_cells: usize,
    /// Cells probed per query; `nprobe == n_cells` is exhaustive and
    /// scores bit-identically to the flat view. With `n_cells = auto`,
    /// values above the resolved cell count clamp (with a warning) at
    /// rebuild time.
    pub nprobe: usize,
}

impl Default for IvfPublishParams {
    fn default() -> Self {
        IvfPublishParams { publish_threshold: 262_144, n_cells: 256, nprobe: 32 }
    }
}

/// Background persistence for the sharded ingest pipeline
/// ([`crate::coordinator::ingest`]): the durable segment store. With
/// `dir` non-empty, every ingested record is appended to its shard's
/// delta log under `dir`, lanes seal immutable segment files past
/// `seal_bytes`, and every `interval_ms` the beat fsyncs the logs +
/// advances the manifest's global-ELO checkpoint — O(delta) per beat,
/// never O(corpus). `eagle serve` recovers from `dir` on restart
/// ([`crate::coordinator::durable`]).
///
/// `interval_ms = 0` disables the periodic beat (the store still appends
/// + seals inline and checkpoints on the admin `snapshot` op and clean
/// shutdown).
///
/// The pre-durable-store whole-JSON background mode is retired: `path`
/// survives only as a **deprecated alias** for the admin `snapshot` op's
/// one-shot JSON target (same effect as `--snapshot-out`; `eagle serve`
/// prints a deprecation notice when it is set). It no longer drives any
/// periodic persistence.
#[derive(Debug, Clone, PartialEq)]
pub struct PersistParams {
    /// Persist at most this often, driven by the applier beat (0 = off).
    pub interval_ms: u64,
    /// Deprecated alias: one-shot JSON target for the admin `snapshot`
    /// op (use `--snapshot-out`; superseded by `dir` for real
    /// persistence).
    pub path: String,
    /// Durable segment-store directory (empty = no background
    /// persistence).
    pub dir: String,
    /// Unsealed delta-log bytes per shard that seal into a segment file.
    pub seal_bytes: usize,
    /// fsync delta logs on the persist beat and segments/manifest at
    /// seal time (disable only for tests/benches).
    pub fsync: bool,
    /// Seal segments in the mmap-friendly v2 column layout and serve
    /// them from the page cache via zero-copy maps on recovery and
    /// follower catch-up (disable to force the v1 frame format and the
    /// buffered decode path everywhere).
    pub mmap: bool,
    /// Background segment compaction beat: merge small adjacent sealed
    /// segments (and upgrade v1 files to v2) at most this often
    /// (0 = compaction off).
    pub compact_interval_ms: u64,
    /// Grace window before a compacted-away segment file is deleted —
    /// long enough for any follower mid-poll on the old manifest cut to
    /// finish or restart.
    pub gc_grace_ms: u64,
}

impl Default for PersistParams {
    fn default() -> Self {
        PersistParams {
            interval_ms: 0,
            path: String::new(),
            dir: String::new(),
            seal_bytes: 4 << 20,
            fsync: true,
            mmap: true,
            compact_interval_ms: 5000,
            gc_grace_ms: 5000,
        }
    }
}

/// SQ8 compressed-corpus scoring ([`crate::vectordb::quant`]): when
/// enabled, the writer quantizes sealed segments to 1-byte/element SQ8
/// codes at publication time (off the route path) and publishes a
/// [`crate::vectordb::quant::QuantView`] instead of the flat view. Scans
/// stream the int8 codes (4x less bandwidth), over-fetch
/// `rerank_factor * k` candidates, and rerank them with the exact f32
/// kernel — returned scores are always exact; quantization can only
/// affect *which* candidates reach the rerank. Segments smaller than the
/// quantizer's row floor stay exact, and IVF publication supersedes this
/// once a shard passes `[ivf] publish_threshold`. The `EAGLE_QUANT` env
/// var (`1`/`0`) overrides `enable` — CI uses it to run the e2e suite on
/// the quantized arm.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    /// Publish SQ8-quantized snapshot views for flat publications.
    pub enable: bool,
    /// Over-fetch multiplier: the quantized scan selects
    /// `rerank_factor * k` candidates for exact rerank. Higher = better
    /// recall, more exact rescores; `recall_ratio >= 0.99` at the default.
    pub rerank_factor: usize,
}

impl Default for QuantParams {
    fn default() -> Self {
        QuantParams {
            enable: false,
            rerank_factor: crate::vectordb::quant::DEFAULT_RERANK_FACTOR,
        }
    }
}

/// Scoring-kernel backend selection ([`crate::vectordb::kernel`]): which
/// SIMD backend every scan dispatches to. `"auto"` (the default) detects
/// the best available backend (AVX2 on x86_64, NEON on aarch64, portable
/// elsewhere); naming a backend forces it, falling back to portable with
/// a warning if the host lacks it. The `EAGLE_KERNEL` env var overrides
/// this setting — that's what CI uses to run the whole suite on the
/// portable arm. All backends score bit-identically (fixed-reduction
/// contract), so this is purely a performance knob.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelParams {
    /// One of `auto`, `portable`, `avx2`, `neon`.
    pub backend: String,
}

impl Default for KernelParams {
    fn default() -> Self {
        KernelParams { backend: "auto".to_string() }
    }
}

/// Serving role in a replicated deployment
/// ([`crate::coordinator::replica`]): the leader owns ingest and the
/// durable log; followers tail the leader's log read-only and serve the
/// route path.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum Role {
    #[default]
    Leader,
    Follower,
}

impl Role {
    /// The wire/config spelling (`hello` advertises this string).
    pub fn as_str(self) -> &'static str {
        match self {
            Role::Leader => "leader",
            Role::Follower => "follower",
        }
    }

    /// Parse a config/CLI/env spelling.
    pub fn parse(s: &str) -> Result<Role, String> {
        match s {
            "leader" => Ok(Role::Leader),
            "follower" => Ok(Role::Follower),
            _ => Err(format!("unknown role '{s}' (expected leader|follower)")),
        }
    }
}

/// Replication parameters ([`crate::coordinator::replica`]). `role`
/// decides whether `eagle serve` owns the durable store (`leader`) or
/// tails another process's store read-only (`follower`; requires
/// `[persist] dir` pointing at the leader's directory). The `EAGLE_ROLE`
/// env var and the `--role` CLI flag override this setting, in that
/// order of increasing precedence.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicaParams {
    /// One of `leader`, `follower`.
    pub role: String,
    /// Follower tail-poll interval in ms (manifest re-read + log scan).
    pub poll_ms: u64,
    /// Cap for the follower's exponential idle backoff: the poll
    /// interval doubles after each no-progress poll up to this many ms
    /// and snaps back to `poll_ms` on any progress. `0` (or any value
    /// at or below `poll_ms`) disables backoff — fixed-interval
    /// polling.
    pub backoff_max_ms: u64,
}

impl Default for ReplicaParams {
    fn default() -> Self {
        ReplicaParams { role: "leader".to_string(), poll_ms: 50, backoff_max_ms: 1000 }
    }
}

/// Default routing policy for the server
/// ([`crate::coordinator::policy`]): applied to every route request that
/// doesn't pick its own policy (all protocol-v1 clients, and v2 routes
/// with no policy/budget fields).
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyParams {
    /// One of `budget`, `cost_aware`, `threshold`.
    pub mode: String,
    /// $ budget for the budget/cost_aware modes; `<= 0` means
    /// unconstrained (route purely on score).
    pub budget: f64,
    /// Win-probability cutoff for the threshold mode, in [0,1].
    pub threshold: f64,
}

impl Default for PolicyParams {
    fn default() -> Self {
        PolicyParams { mode: "budget".to_string(), budget: 0.0, threshold: 0.5 }
    }
}

impl PolicyParams {
    /// The parsed spec (validation errors name the bad knob).
    pub fn spec(&self) -> Result<crate::coordinator::policy::PolicySpec, String> {
        crate::coordinator::policy::PolicySpec::from_mode(&self.mode, self.budget, self.threshold)
    }
}

/// Synthetic RouterBench generation parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct DataParams {
    pub seed: u64,
    /// Prompts per dataset.
    pub per_dataset: usize,
    /// Train fraction (rest is test), paper: 0.7.
    pub train_fraction: f64,
    /// Pairwise comparisons sampled per training prompt.
    pub comparisons_per_prompt: usize,
}

impl Default for DataParams {
    fn default() -> Self {
        DataParams {
            seed: 0xEA61E,
            per_dataset: 2800,
            train_fraction: 0.7,
            comparisons_per_prompt: 3,
        }
    }
}

/// Top-level configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    pub eagle: EagleParams,
    pub baselines: BaselineParams,
    pub embed: EmbedParams,
    pub server: ServerParams,
    pub epoch: EpochParams,
    pub shards: ShardParams,
    pub ivf: IvfPublishParams,
    pub quant: QuantParams,
    pub persist: PersistParams,
    pub kernel: KernelParams,
    pub replica: ReplicaParams,
    pub policy: PolicyParams,
    pub data: DataParams,
}

/// Raw parsed file: section -> key -> raw value string.
type RawConfig = BTreeMap<String, BTreeMap<String, String>>;

/// Error type for config parsing/validation.
#[derive(Debug)]
pub struct ConfigError(pub String);

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

fn parse_raw(text: &str) -> Result<RawConfig, ConfigError> {
    let mut raw: RawConfig = BTreeMap::new();
    let mut section = String::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            section = name.trim().to_string();
            raw.entry(section.clone()).or_default();
            continue;
        }
        let (key, value) = line.split_once('=').ok_or_else(|| {
            ConfigError(format!("line {}: expected 'key = value'", lineno + 1))
        })?;
        let value = value.trim().trim_matches('"').to_string();
        raw.entry(section.clone())
            .or_default()
            .insert(key.trim().to_string(), value);
    }
    Ok(raw)
}

impl Config {
    /// Defaults + file (if given) + overrides, in that order.
    pub fn load(
        path: Option<&Path>,
        overrides: &[(String, String)],
    ) -> Result<Config, ConfigError> {
        let mut cfg = Config::default();
        if let Some(p) = path {
            let text = std::fs::read_to_string(p)
                .map_err(|e| ConfigError(format!("read {}: {e}", p.display())))?;
            cfg.apply_raw(&parse_raw(&text)?)?;
        }
        for (k, v) in overrides {
            cfg.set(k, v)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    fn apply_raw(&mut self, raw: &RawConfig) -> Result<(), ConfigError> {
        for (section, entries) in raw {
            for (key, value) in entries {
                self.set(&format!("{section}.{key}"), value)?;
            }
        }
        Ok(())
    }

    /// Set one dotted-path field from a string value.
    pub fn set(&mut self, path: &str, value: &str) -> Result<(), ConfigError> {
        fn f64_of(v: &str) -> Result<f64, ConfigError> {
            v.parse().map_err(|_| ConfigError(format!("bad float '{v}'")))
        }
        fn usize_of(v: &str) -> Result<usize, ConfigError> {
            v.parse().map_err(|_| ConfigError(format!("bad integer '{v}'")))
        }
        fn u64_of(v: &str) -> Result<u64, ConfigError> {
            v.parse().map_err(|_| ConfigError(format!("bad integer '{v}'")))
        }
        fn bool_of(v: &str) -> Result<bool, ConfigError> {
            match v {
                "true" | "1" | "yes" => Ok(true),
                "false" | "0" | "no" => Ok(false),
                _ => Err(ConfigError(format!("bad bool '{v}'"))),
            }
        }
        match path {
            "eagle.p" => self.eagle.p = f64_of(value)?,
            "eagle.n_neighbors" => self.eagle.n_neighbors = usize_of(value)?,
            "eagle.k_factor" => self.eagle.k_factor = f64_of(value)?,
            "baselines.knn_neighbors" => self.baselines.knn_neighbors = usize_of(value)?,
            "baselines.mlp_hidden" => self.baselines.mlp_hidden = usize_of(value)?,
            "baselines.mlp_epochs" => self.baselines.mlp_epochs = usize_of(value)?,
            "baselines.mlp_lr" => self.baselines.mlp_lr = f64_of(value)?,
            "baselines.svm_epsilon" => self.baselines.svm_epsilon = f64_of(value)?,
            "baselines.svm_epochs" => self.baselines.svm_epochs = usize_of(value)?,
            "baselines.svm_lr" => self.baselines.svm_lr = f64_of(value)?,
            "embed.artifacts_dir" => self.embed.artifacts_dir = value.to_string(),
            "embed.batch_window_us" => self.embed.batch_window_us = u64_of(value)?,
            "embed.max_batch" => self.embed.max_batch = usize_of(value)?,
            "server.addr" => self.server.addr = value.to_string(),
            "server.workers" => self.server.workers = usize_of(value)?,
            "server.max_connections" => self.server.max_connections = usize_of(value)?,
            "server.max_inflight" => self.server.max_inflight = usize_of(value)?,
            "server.idle_timeout_ms" => self.server.idle_timeout_ms = u64_of(value)?,
            "epoch.publish_every" => self.epoch.publish_every = usize_of(value)?,
            "epoch.publish_interval_ms" => self.epoch.publish_interval_ms = u64_of(value)?,
            "shards.count" => self.shards.count = usize_of(value)?,
            "shards.hash_seed" => self.shards.hash_seed = u64_of(value)?,
            "ivf.publish_threshold" => self.ivf.publish_threshold = usize_of(value)?,
            // `auto` (== 0) defers n_cells to sqrt(corpus) at rebuild time
            "ivf.n_cells" => {
                self.ivf.n_cells = if value == "auto" { 0 } else { usize_of(value)? }
            }
            "ivf.nprobe" => self.ivf.nprobe = usize_of(value)?,
            "quant.enable" => self.quant.enable = bool_of(value)?,
            "quant.rerank_factor" => self.quant.rerank_factor = usize_of(value)?,
            "persist.interval_ms" => self.persist.interval_ms = u64_of(value)?,
            "persist.path" => self.persist.path = value.to_string(),
            "persist.dir" => self.persist.dir = value.to_string(),
            "persist.seal_bytes" => self.persist.seal_bytes = usize_of(value)?,
            "persist.fsync" => self.persist.fsync = bool_of(value)?,
            "persist.mmap" => self.persist.mmap = bool_of(value)?,
            "persist.compact_interval_ms" => self.persist.compact_interval_ms = u64_of(value)?,
            "persist.gc_grace_ms" => self.persist.gc_grace_ms = u64_of(value)?,
            "kernel.backend" => self.kernel.backend = value.to_string(),
            "replica.role" => self.replica.role = value.to_string(),
            "replica.poll_ms" => self.replica.poll_ms = u64_of(value)?,
            "replica.backoff_max_ms" => self.replica.backoff_max_ms = u64_of(value)?,
            "policy.mode" => self.policy.mode = value.to_string(),
            "policy.budget" => self.policy.budget = f64_of(value)?,
            "policy.threshold" => self.policy.threshold = f64_of(value)?,
            "data.seed" => self.data.seed = u64_of(value)?,
            "data.per_dataset" => self.data.per_dataset = usize_of(value)?,
            "data.train_fraction" => self.data.train_fraction = f64_of(value)?,
            "data.comparisons_per_prompt" => {
                self.data.comparisons_per_prompt = usize_of(value)?
            }
            _ => return Err(ConfigError(format!("unknown config key '{path}'"))),
        }
        Ok(())
    }

    /// Sanity constraints.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if !(0.0..=1.0).contains(&self.eagle.p) {
            return Err(ConfigError(format!("eagle.p = {} not in [0,1]", self.eagle.p)));
        }
        if self.eagle.n_neighbors == 0 {
            return Err(ConfigError("eagle.n_neighbors must be > 0".into()));
        }
        if self.eagle.k_factor <= 0.0 {
            return Err(ConfigError("eagle.k_factor must be > 0".into()));
        }
        if !(0.0..1.0).contains(&self.data.train_fraction) || self.data.train_fraction == 0.0 {
            return Err(ConfigError("data.train_fraction must be in (0,1)".into()));
        }
        if self.server.workers == 0 {
            return Err(ConfigError("server.workers must be > 0".into()));
        }
        if self.server.max_connections == 0 {
            return Err(ConfigError("server.max_connections must be > 0".into()));
        }
        if self.server.max_inflight == 0 {
            return Err(ConfigError("server.max_inflight must be > 0".into()));
        }
        if self.embed.max_batch == 0 {
            return Err(ConfigError("embed.max_batch must be > 0".into()));
        }
        if self.epoch.publish_every == 0 {
            return Err(ConfigError("epoch.publish_every must be > 0".into()));
        }
        if self.shards.count == 0 || self.shards.count > 64 {
            return Err(ConfigError(format!(
                "shards.count = {} not in 1..=64",
                self.shards.count
            )));
        }
        if self.ivf.publish_threshold > 0 {
            // n_cells == 0 means `auto` (resolved to sqrt(corpus) at
            // rebuild time), so nprobe can only be range-checked against
            // an explicit cell count; auto clamps at rebuild instead.
            if self.ivf.nprobe == 0 {
                return Err(ConfigError("ivf.nprobe must be > 0".into()));
            }
            if self.ivf.n_cells > 0 && self.ivf.nprobe > self.ivf.n_cells {
                return Err(ConfigError(format!(
                    "ivf.nprobe = {} not in 1..=n_cells ({})",
                    self.ivf.nprobe, self.ivf.n_cells
                )));
            }
        }
        if self.quant.enable && self.quant.rerank_factor == 0 {
            return Err(ConfigError("quant.rerank_factor must be > 0".into()));
        }
        if self.persist.seal_bytes == 0 {
            return Err(ConfigError("persist.seal_bytes must be > 0".into()));
        }
        crate::vectordb::kernel::parse_choice(&self.kernel.backend)
            .map_err(|e| ConfigError(format!("kernel.backend: {e}")))?;
        Role::parse(&self.replica.role)
            .map_err(|e| ConfigError(format!("replica.role: {e}")))?;
        if self.replica.poll_ms == 0 {
            return Err(ConfigError("replica.poll_ms must be > 0".into()));
        }
        self.policy.spec().map_err(|e| ConfigError(format!("policy: {e}")))?;
        Ok(())
    }
}

/// One resolution rule for env > config > default knobs (`EAGLE_KERNEL`,
/// `EAGLE_QUANT`, `EAGLE_ROLE`): if `var` is set and parses, it wins
/// over `configured` with a note on stderr; if it is set but malformed,
/// warn and keep `configured`; if unset, keep `configured`. `what` names
/// the knob in both messages (e.g. `"[quant] enable"`).
pub fn env_override<T, F>(var: &str, what: &str, configured: T, parse: F) -> T
where
    F: FnOnce(&str) -> Result<T, String>,
{
    let Ok(raw) = std::env::var(var) else {
        return configured;
    };
    let raw = raw.trim().to_string();
    match parse(&raw) {
        Ok(v) => {
            eprintln!("note: {var}={raw} overrides {what}");
            v
        }
        Err(e) => {
            eprintln!("warning: {var}: {e}; keeping {what}");
            configured
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_appendix_a() {
        let c = Config::default();
        assert_eq!(c.eagle.p, 0.5);
        assert_eq!(c.eagle.n_neighbors, 20);
        assert_eq!(c.eagle.k_factor, 32.0);
        assert_eq!(c.baselines.knn_neighbors, 40);
        assert_eq!(c.baselines.mlp_hidden, 100);
        assert_eq!(c.baselines.svm_epsilon, 0.0);
        assert_eq!(c.data.train_fraction, 0.7);
    }

    #[test]
    fn parse_file_sections() {
        let text = r#"
# comment
[eagle]
p = 0.7          # inline comment
n_neighbors = 10

[server]
addr = "0.0.0.0:9000"
workers = 8
"#;
        let raw = parse_raw(text).unwrap();
        let mut c = Config::default();
        c.apply_raw(&raw).unwrap();
        assert_eq!(c.eagle.p, 0.7);
        assert_eq!(c.eagle.n_neighbors, 10);
        assert_eq!(c.server.addr, "0.0.0.0:9000");
        assert_eq!(c.server.workers, 8);
    }

    #[test]
    fn overrides_win_over_defaults() {
        let c = Config::load(
            None,
            &[("eagle.p".into(), "0.25".into()), ("data.seed".into(), "7".into())],
        )
        .unwrap();
        assert_eq!(c.eagle.p, 0.25);
        assert_eq!(c.data.seed, 7);
    }

    #[test]
    fn epoch_knobs_parse_and_validate() {
        let c = Config::load(
            None,
            &[
                ("epoch.publish_every".into(), "16".into()),
                ("epoch.publish_interval_ms".into(), "5".into()),
            ],
        )
        .unwrap();
        assert_eq!(c.epoch.publish_every, 16);
        assert_eq!(c.epoch.publish_interval_ms, 5);
        assert_eq!(Config::default().epoch, EpochParams::default());
        let mut bad = Config::default();
        bad.epoch.publish_every = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn shard_knobs_parse_and_validate() {
        let c = Config::load(
            None,
            &[
                ("shards.count".into(), "8".into()),
                ("shards.hash_seed".into(), "42".into()),
            ],
        )
        .unwrap();
        assert_eq!(c.shards.count, 8);
        assert_eq!(c.shards.hash_seed, 42);
        assert_eq!(Config::default().shards, ShardParams::default());
        assert_eq!(ShardParams::default().count, 1);
        let mut bad = Config::default();
        bad.shards.count = 0;
        assert!(bad.validate().is_err());
        bad.shards.count = 65;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn ivf_and_persist_knobs_parse_and_validate() {
        let c = Config::load(
            None,
            &[
                ("ivf.publish_threshold".into(), "5000".into()),
                ("ivf.n_cells".into(), "32".into()),
                ("ivf.nprobe".into(), "32".into()),
                ("persist.interval_ms".into(), "250".into()),
                ("persist.path".into(), "/tmp/eagle.json".into()),
                ("persist.dir".into(), "/tmp/eagle-durable".into()),
                ("persist.seal_bytes".into(), "65536".into()),
                ("persist.fsync".into(), "false".into()),
                ("persist.mmap".into(), "false".into()),
                ("persist.compact_interval_ms".into(), "1500".into()),
                ("persist.gc_grace_ms".into(), "2500".into()),
            ],
        )
        .unwrap();
        assert_eq!(c.ivf.publish_threshold, 5000);
        assert_eq!(c.ivf.n_cells, 32);
        assert_eq!(c.ivf.nprobe, 32);
        assert_eq!(c.persist.interval_ms, 250);
        assert_eq!(c.persist.path, "/tmp/eagle.json");
        assert_eq!(c.persist.dir, "/tmp/eagle-durable");
        assert_eq!(c.persist.seal_bytes, 65536);
        assert!(!c.persist.fsync);
        assert!(!c.persist.mmap);
        assert_eq!(c.persist.compact_interval_ms, 1500);
        assert_eq!(c.persist.gc_grace_ms, 2500);
        // durable-store knobs: defaults + validation
        let d = PersistParams::default();
        assert!(d.dir.is_empty());
        assert!(d.fsync);
        assert!(d.mmap);
        assert!(d.compact_interval_ms > 0);
        assert!(d.gc_grace_ms > 0);
        assert!(d.seal_bytes >= 1 << 20);
        assert!(Config::default().set("persist.mmap", "maybe").is_err());
        let mut bad = Config::default();
        bad.persist.seal_bytes = 0;
        assert!(bad.validate().is_err());
        assert!(Config::default().set("persist.fsync", "maybe").is_err());
        // defaults: IVF engages only at production-scale corpora, no
        // periodic persistence
        assert_eq!(Config::default().persist, PersistParams::default());
        assert_eq!(PersistParams::default().interval_ms, 0);
        assert!(IvfPublishParams::default().publish_threshold > 100_000);
        // nprobe must stay within the cell count when IVF is enabled
        let mut bad = Config::default();
        bad.ivf.publish_threshold = 100;
        bad.ivf.nprobe = bad.ivf.n_cells + 1;
        assert!(bad.validate().is_err());
        bad.ivf.nprobe = 0;
        assert!(bad.validate().is_err());
        // ...but is unconstrained while IVF publication is disabled
        bad.ivf.publish_threshold = 0;
        assert!(bad.validate().is_ok());
    }

    #[test]
    fn ivf_n_cells_auto_parses_and_validates() {
        // `auto` and `0` both mean sqrt(corpus)-at-rebuild
        let c = Config::load(None, &[("ivf.n_cells".into(), "auto".into())]).unwrap();
        assert_eq!(c.ivf.n_cells, 0);
        let c = Config::load(None, &[("ivf.n_cells".into(), "0".into())]).unwrap();
        assert_eq!(c.ivf.n_cells, 0);
        // with auto cells, any positive nprobe validates (clamped at
        // rebuild time against the resolved cell count)...
        let c = Config::load(
            None,
            &[
                ("ivf.publish_threshold".into(), "100".into()),
                ("ivf.n_cells".into(), "auto".into()),
                ("ivf.nprobe".into(), "10000".into()),
            ],
        )
        .unwrap();
        assert_eq!(c.ivf.n_cells, 0);
        assert_eq!(c.ivf.nprobe, 10_000);
        // ...but nprobe = 0 is still rejected
        let mut bad = Config::default();
        bad.ivf.publish_threshold = 100;
        bad.ivf.n_cells = 0;
        bad.ivf.nprobe = 0;
        assert!(bad.validate().is_err());
        // garbage still rejected
        assert!(Config::default().set("ivf.n_cells", "lots").is_err());
    }

    #[test]
    fn quant_knobs_parse_and_validate() {
        // defaults: off, rerank factor from the quantizer module
        let c = Config::default();
        assert_eq!(c.quant, QuantParams::default());
        assert!(!c.quant.enable);
        assert_eq!(
            c.quant.rerank_factor,
            crate::vectordb::quant::DEFAULT_RERANK_FACTOR
        );
        let c = Config::load(
            None,
            &[
                ("quant.enable".into(), "true".into()),
                ("quant.rerank_factor".into(), "8".into()),
            ],
        )
        .unwrap();
        assert!(c.quant.enable);
        assert_eq!(c.quant.rerank_factor, 8);
        // rerank_factor = 0 invalid only while quantization is on
        let mut bad = Config::default();
        bad.quant.rerank_factor = 0;
        assert!(bad.validate().is_ok());
        bad.quant.enable = true;
        assert!(bad.validate().is_err());
        assert!(Config::default().set("quant.enable", "maybe").is_err());
    }

    #[test]
    fn admission_knobs_parse_and_validate() {
        let c = Config::load(
            None,
            &[
                ("server.max_connections".into(), "128".into()),
                ("server.max_inflight".into(), "16".into()),
                ("server.idle_timeout_ms".into(), "5000".into()),
            ],
        )
        .unwrap();
        assert_eq!(c.server.max_connections, 128);
        assert_eq!(c.server.max_inflight, 16);
        assert_eq!(c.server.idle_timeout_ms, 5000);
        assert_eq!(Config::default().server, ServerParams::default());
        let mut bad = Config::default();
        bad.server.max_connections = 0;
        assert!(bad.validate().is_err());
        let mut bad = Config::default();
        bad.server.max_inflight = 0;
        assert!(bad.validate().is_err());
        // idle_timeout_ms = 0 is valid: it disables the sweep
        let mut c = Config::default();
        c.server.idle_timeout_ms = 0;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn kernel_backend_parses_and_validates() {
        assert_eq!(Config::default().kernel.backend, "auto");
        let c = Config::load(None, &[("kernel.backend".into(), "portable".into())]).unwrap();
        assert_eq!(c.kernel.backend, "portable");
        for good in ["auto", "portable", "avx2", "neon"] {
            let mut c = Config::default();
            c.kernel.backend = good.to_string();
            assert!(c.validate().is_ok(), "{good} rejected");
        }
        let mut bad = Config::default();
        bad.kernel.backend = "sse9".to_string();
        let err = bad.validate().unwrap_err();
        assert!(err.0.contains("kernel.backend"), "{}", err.0);
    }

    #[test]
    fn policy_knobs_parse_and_validate() {
        use crate::coordinator::policy::PolicySpec;
        // defaults: unconstrained budget policy
        let c = Config::default();
        assert_eq!(c.policy, PolicyParams::default());
        assert_eq!(
            c.policy.spec().unwrap(),
            PolicySpec::Budget { budget: f64::INFINITY }
        );
        let c = Config::load(
            None,
            &[
                ("policy.mode".into(), "threshold".into()),
                ("policy.threshold".into(), "0.7".into()),
            ],
        )
        .unwrap();
        assert_eq!(c.policy.spec().unwrap(), PolicySpec::Threshold { threshold: 0.7 });
        let c = Config::load(
            None,
            &[
                ("policy.mode".into(), "cost_aware".into()),
                ("policy.budget".into(), "0.02".into()),
            ],
        )
        .unwrap();
        assert_eq!(c.policy.spec().unwrap(), PolicySpec::CostAware { budget: 0.02 });
        // bad mode and out-of-range threshold are validation errors
        let mut bad = Config::default();
        bad.policy.mode = "nope".into();
        let err = bad.validate().unwrap_err();
        assert!(err.0.contains("policy"), "{}", err.0);
        let mut bad = Config::default();
        bad.policy.mode = "threshold".into();
        bad.policy.threshold = 1.5;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn replica_knobs_parse_and_validate() {
        let c = Config::default();
        assert_eq!(c.replica, ReplicaParams::default());
        assert_eq!(c.replica.role, "leader");
        assert_eq!(Role::default(), Role::Leader);
        let c = Config::load(
            None,
            &[
                ("replica.role".into(), "follower".into()),
                ("replica.poll_ms".into(), "10".into()),
                ("replica.backoff_max_ms".into(), "750".into()),
            ],
        )
        .unwrap();
        assert_eq!(Role::parse(&c.replica.role).unwrap(), Role::Follower);
        assert_eq!(c.replica.poll_ms, 10);
        assert_eq!(c.replica.backoff_max_ms, 750);
        // 0 (or anything at or below poll_ms) is valid: backoff off
        let mut fixed = Config::default();
        fixed.replica.backoff_max_ms = 0;
        assert!(fixed.validate().is_ok());
        assert_eq!(Role::Leader.as_str(), "leader");
        assert_eq!(Role::Follower.as_str(), "follower");
        let mut bad = Config::default();
        bad.replica.role = "primary".into();
        let err = bad.validate().unwrap_err();
        assert!(err.0.contains("replica.role"), "{}", err.0);
        let mut bad = Config::default();
        bad.replica.poll_ms = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn env_override_resolution_order() {
        // Env var names are process-global state: use ones no other test
        // (or the kernel/quant plumbing) reads.
        let parse = |s: &str| Role::parse(s);
        std::env::remove_var("EAGLE_TEST_UNSET");
        assert_eq!(
            env_override("EAGLE_TEST_UNSET", "role", Role::Leader, parse),
            Role::Leader
        );
        std::env::set_var("EAGLE_TEST_ROLE_OK", " follower ");
        assert_eq!(
            env_override("EAGLE_TEST_ROLE_OK", "role", Role::Leader, parse),
            Role::Follower
        );
        std::env::set_var("EAGLE_TEST_ROLE_BAD", "primary");
        assert_eq!(
            env_override("EAGLE_TEST_ROLE_BAD", "role", Role::Leader, parse),
            Role::Leader
        );
        std::env::remove_var("EAGLE_TEST_ROLE_OK");
        std::env::remove_var("EAGLE_TEST_ROLE_BAD");
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = Config::default();
        assert!(c.set("eagle.nope", "1").is_err());
        assert!(c.set("nonsense", "1").is_err());
    }

    #[test]
    fn bad_values_rejected() {
        let mut c = Config::default();
        assert!(c.set("eagle.p", "abc").is_err());
        assert!(c.set("server.workers", "-1").is_err());
    }

    #[test]
    fn validation_bounds() {
        let mut c = Config::default();
        c.eagle.p = 1.5;
        assert!(c.validate().is_err());
        c.eagle.p = 0.5;
        c.eagle.n_neighbors = 0;
        assert!(c.validate().is_err());
        c.eagle.n_neighbors = 20;
        c.data.train_fraction = 1.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn malformed_line_reported_with_lineno() {
        let err = parse_raw("[a]\nthis is not kv").unwrap_err();
        assert!(err.0.contains("line 2"), "{}", err.0);
    }

    #[test]
    fn load_missing_file_errors() {
        assert!(Config::load(Some(Path::new("/nonexistent/x.toml")), &[]).is_err());
    }
}
