//! Hash tokenizer — bit-identical mirror of `python/compile/tokenizer.py`.
//!
//! The rust coordinator tokenizes on the request path; the python compile
//! path tokenizes when generating golden vectors. Both sides pin the same
//! golden values in their test suites (change one side, change both):
//!
//! 1. lowercase (ASCII folding only),
//! 2. split into maximal ASCII-alphanumeric runs,
//! 3. id = `1 + FNV1a64(word) % (vocab - 1)`,
//! 4. truncate / right-pad with `PAD_ID` (=0) to `seq_len`.

/// Vocabulary size baked into the MiniStella artifacts.
pub const VOCAB_SIZE: u32 = 8192;
/// Sequence length baked into the MiniStella artifacts.
pub const SEQ_LEN: usize = 64;
/// Padding token id.
pub const PAD_ID: i32 = 0;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// 64-bit FNV-1a hash.
pub fn fnv1a64(data: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Lowercased maximal ASCII-alphanumeric runs, in order.
pub fn words(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for ch in text.chars() {
        match ch {
            'A'..='Z' => cur.push(ch.to_ascii_lowercase()),
            'a'..='z' | '0'..='9' => cur.push(ch),
            _ => {
                if !cur.is_empty() {
                    out.push(std::mem::take(&mut cur));
                }
            }
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// Token id of a single (already lowercased) word.
pub fn word_id(word: &str, vocab_size: u32) -> i32 {
    (1 + fnv1a64(word.as_bytes()) % (vocab_size as u64 - 1)) as i32
}

/// Tokenized prompt: ids + mask, both `seq_len` long.
#[derive(Debug, Clone, PartialEq)]
pub struct Tokenized {
    pub ids: Vec<i32>,
    pub mask: Vec<f32>,
}

impl Tokenized {
    /// Number of real (non-padding) tokens.
    pub fn len(&self) -> usize {
        self.mask.iter().filter(|&&m| m == 1.0).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Tokenize `text` into exactly `seq_len` (id, mask) pairs.
pub fn tokenize(text: &str, seq_len: usize, vocab_size: u32) -> Tokenized {
    let mut ids: Vec<i32> = words(text)
        .iter()
        .take(seq_len)
        .map(|w| word_id(w, vocab_size))
        .collect();
    let real = ids.len();
    ids.resize(seq_len, PAD_ID);
    let mut mask = vec![1.0f32; real];
    mask.resize(seq_len, 0.0);
    Tokenized { ids, mask }
}

/// Tokenize with the artifact defaults (SEQ_LEN, VOCAB_SIZE).
pub fn tokenize_default(text: &str) -> Tokenized {
    tokenize(text, SEQ_LEN, VOCAB_SIZE)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    // ---- golden values duplicated in python/tests/test_tokenizer.py ----

    #[test]
    fn golden_fnv_hello() {
        assert_eq!(fnv1a64(b"hello"), 11831194018420276491);
    }

    #[test]
    fn golden_fnv_empty() {
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
    }

    #[test]
    fn golden_word_ids() {
        assert_eq!(word_id("hello", VOCAB_SIZE), 8181);
        assert_eq!(word_id("world", VOCAB_SIZE), 5097);
        assert_eq!(word_id("the", VOCAB_SIZE), 4062);
        assert_eq!(word_id("42", VOCAB_SIZE), 5912);
    }

    #[test]
    fn golden_tokenize() {
        let t = tokenize("Hello, World! 42", 8, VOCAB_SIZE);
        assert_eq!(t.ids, vec![8181, 5097, 5912, 0, 0, 0, 0, 0]);
        assert_eq!(t.mask, vec![1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn golden_words_split() {
        assert_eq!(words("a-b_c  D9"), vec!["a", "b", "c", "d9"]);
    }

    // ---- behavior ----

    #[test]
    fn unicode_is_separator() {
        assert_eq!(words("caf\u{e9} bar"), vec!["caf", "bar"]);
    }

    #[test]
    fn truncation() {
        let long = vec!["w"; 100].join(" ");
        let t = tokenize(&long, 16, VOCAB_SIZE);
        assert_eq!(t.ids.len(), 16);
        assert_eq!(t.len(), 16);
    }

    #[test]
    fn empty_text() {
        let t = tokenize("", 8, VOCAB_SIZE);
        assert!(t.is_empty());
        assert_eq!(t.ids, vec![0; 8]);
    }

    #[test]
    fn ids_in_vocab_range() {
        prop::check("token ids in range", 200, |rng| {
            let text = prop::sentence(rng, 20);
            let t = tokenize(&text, SEQ_LEN, VOCAB_SIZE);
            prop::assert_prop(
                t.ids.iter().all(|&i| (0..VOCAB_SIZE as i32).contains(&i)),
                "id out of range",
            )
        });
    }

    #[test]
    fn mask_is_prefix_of_ones() {
        prop::check("mask prefix", 200, |rng| {
            let text = prop::sentence(rng, 80);
            let t = tokenize(&text, 32, VOCAB_SIZE);
            let first_pad = t.mask.iter().position(|&m| m == 0.0).unwrap_or(32);
            for (i, (&id, &m)) in t.ids.iter().zip(&t.mask).enumerate() {
                prop::assert_prop((m == 1.0) == (i < first_pad), "mask not prefix")?;
                prop::assert_prop((m == 1.0) == (id != PAD_ID), "mask/id mismatch")?;
            }
            Ok(())
        });
    }

    #[test]
    fn deterministic() {
        let a = tokenize("some fixed text 123", 32, VOCAB_SIZE);
        let b = tokenize("some fixed text 123", 32, VOCAB_SIZE);
        assert_eq!(a, b);
    }

    #[test]
    fn case_and_punct_insensitive() {
        let a = tokenize("Hello World", 8, VOCAB_SIZE);
        let b = tokenize("hello, world!!!", 8, VOCAB_SIZE);
        assert_eq!(a, b);
    }
}
