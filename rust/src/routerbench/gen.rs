//! Deterministic generator for the synthetic RouterBench benchmark.
//!
//! See the module docs in [`super`] for the statistical design. The load-
//! bearing properties (checked by tests):
//!
//! 1. same-(dataset, topic) prompts share keyword tokens => they cluster
//!    under any token-overlap-preserving embedder (MiniStella, HashEmbedder);
//! 2. model quality orderings differ *across* datasets and *across* topics
//!    within a dataset — routing has signal to find;
//! 3. quality is a noisy draw per sample — routers must generalize, not
//!    memorize;
//! 4. the whole benchmark is a pure function of `DataParams`.

use crate::config::DataParams;
use crate::util::Rng;

use super::models::{ModelSpec, MODELS};
use super::{
    outcome_from_quality, Benchmark, DatasetSplit, FeedbackRecord, Sample, DATASETS,
    TOPICS_PER_DATASET,
};
use crate::elo::Comparison;

/// Per-dataset prompt scaffolding: (prefix pool, suffix pool).
const PREFIXES: &[(&str, &[&str], &[&str])] = &[
    (
        "mmlu",
        &[
            "Which of the following statements about",
            "According to standard theory, the correct answer regarding",
            "Choose the best option concerning",
            "In an exam question about",
        ],
        &["is correct?", "best explains the phenomenon?", "holds true?", "applies here?"],
    ),
    (
        "hellaswag",
        &[
            "Finish the sentence naturally:",
            "What happens next in this scene about",
            "Pick the most plausible continuation involving",
            "Complete this everyday situation about",
        ],
        &["in the most sensible way", "so the story flows", "given common sense", "naturally"],
    ),
    (
        "gsm8k",
        &[
            "Solve this word problem about",
            "A grade school math question involving",
            "Compute the answer step by step for",
            "Work out the arithmetic in this story about",
        ],
        &["show your reasoning", "give the final number", "explain each step", "what is the total?"],
    ),
    (
        "arc-challenge",
        &[
            "A science exam question about",
            "Which scientific principle explains",
            "Reason about this grade school science item on",
            "Select the correct science answer about",
        ],
        &["choose one option", "justify briefly", "which is right?", "pick the best answer"],
    ),
    (
        "winogrande",
        &[
            "Resolve the pronoun in this sentence about",
            "Who does 'they' refer to in the scenario about",
            "Fill in the blank with the right entity:",
            "Commonsense coreference puzzle involving",
        ],
        &["explain the reference", "which entity fits?", "resolve the ambiguity", "pick the referent"],
    ),
    (
        "mbpp",
        &[
            "Write a python function that",
            "Implement code to",
            "Complete this programming task:",
            "Produce a short python snippet that",
        ],
        &["include a docstring", "handle edge cases", "return the result", "with unit tests"],
    ),
    (
        "mt-bench",
        &[
            "In a multi turn conversation, the user asks about",
            "Compose a helpful assistant reply concerning",
            "Follow up thoughtfully on a question about",
            "Draft a detailed yet concise response about",
        ],
        &["address the follow up", "keep the tone friendly", "structure the answer", "be specific"],
    ),
];

/// Topic keyword banks: TOPICS_PER_DATASET topics x 4 keywords, per dataset.
/// Keywords are the cluster anchors — every prompt from a topic includes
/// 2–3 of them.
const TOPIC_KEYWORDS: &[&[&[&str]]] = &[
    // mmlu
    &[
        &["anatomy", "organ", "tissue", "physiology"],
        &["astronomy", "planet", "orbit", "telescope"],
        &["microeconomics", "market", "elasticity", "demand"],
        &["jurisprudence", "statute", "precedent", "liability"],
        &["virology", "pathogen", "vaccine", "antibody"],
        &["philosophy", "ethics", "epistemology", "metaphysics"],
        &["electrical", "circuit", "voltage", "resistor"],
        &["geography", "climate", "continent", "biome"],
    ],
    // hellaswag
    &[
        &["cooking", "kitchen", "recipe", "oven"],
        &["skateboard", "ramp", "trick", "helmet"],
        &["gardening", "soil", "seedling", "watering"],
        &["swimming", "pool", "stroke", "goggles"],
        &["camping", "tent", "campfire", "sleeping"],
        &["haircut", "salon", "scissors", "stylist"],
        &["fishing", "rod", "bait", "riverbank"],
        &["painting", "canvas", "brush", "easel"],
    ],
    // gsm8k
    &[
        &["apples", "baskets", "orchard", "dozen"],
        &["train", "speed", "distance", "hours"],
        &["allowance", "savings", "dollars", "weekly"],
        &["bakery", "loaves", "flour", "batches"],
        &["marbles", "bags", "shared", "friends"],
        &["fence", "perimeter", "meters", "posts"],
        &["tickets", "concert", "rows", "seats"],
        &["paint", "gallons", "walls", "coats"],
    ],
    // arc-challenge
    &[
        &["photosynthesis", "chlorophyll", "sunlight", "glucose"],
        &["magnets", "poles", "attract", "repel"],
        &["erosion", "sediment", "weathering", "riverbed"],
        &["food", "chain", "predator", "herbivore"],
        &["states", "matter", "evaporation", "condensation"],
        &["inheritance", "traits", "genes", "offspring"],
        &["gravity", "mass", "acceleration", "falling"],
        &["volcano", "magma", "eruption", "crust"],
    ],
    // winogrande
    &[
        &["trophy", "suitcase", "fit", "because"],
        &["doctor", "patient", "appointment", "because"],
        &["neighbor", "ladder", "borrowed", "because"],
        &["teacher", "student", "homework", "because"],
        &["waiter", "customer", "order", "because"],
        &["plumber", "homeowner", "leak", "because"],
        &["coach", "player", "practice", "because"],
        &["librarian", "visitor", "book", "because"],
    ],
    // mbpp
    &[
        &["sort", "list", "ascending", "integers"],
        &["string", "reverse", "palindrome", "characters"],
        &["dictionary", "keys", "merge", "values"],
        &["prime", "factorial", "number", "compute"],
        &["matrix", "transpose", "rows", "columns"],
        &["file", "read", "lines", "parse"],
        &["regex", "match", "pattern", "extract"],
        &["recursion", "fibonacci", "sequence", "memoize"],
    ],
    // mt-bench
    &[
        &["travel", "itinerary", "hawaii", "attractions"],
        &["resume", "career", "interview", "skills"],
        &["startup", "pitch", "investors", "revenue"],
        &["nutrition", "diet", "protein", "meals"],
        &["novel", "plot", "character", "chapter"],
        &["economics", "inflation", "policy", "rates"],
        &["parenting", "toddler", "routine", "bedtime"],
        &["chess", "opening", "strategy", "endgame"],
    ],
];

/// Small shared filler pool plus an unbounded pseudo-word generator.
///
/// Real prompts carry heavy prompt-specific vocabulary (names, numbers,
/// phrasing) that embeds as per-prompt noise on top of the topical signal;
/// a tiny closed filler pool would make topic clusters unrealistically
/// clean and per-query regression unrealistically easy. `gibberish`
/// produces deterministic unique words, emulating that long tail.
const FILLERS: &[&str] = &[
    "please", "carefully", "consider", "the", "given", "details", "and", "provide",
    "an", "answer", "that", "is", "clear", "complete", "correct", "for", "this",
    "specific", "case", "with", "all", "relevant", "information", "included",
];

/// A deterministic pseudo-word of 3-8 lowercase letters.
fn gibberish(rng: &mut Rng) -> String {
    let len = 3 + rng.below(6);
    (0..len).map(|_| (b'a' + rng.below(26) as u8) as char).collect()
}

/// Latent per-(model, dataset, topic) skill table.
#[derive(Debug, Clone)]
pub struct SkillTable {
    /// [model][dataset][topic] -> skill in [0,1]
    skills: Vec<Vec<Vec<f64>>>,
}

impl SkillTable {
    /// Deterministic skills: spec base + per-topic affinity noise.
    pub fn generate(seed: u64) -> SkillTable {
        let mut root = Rng::with_stream(seed, 0x5111);
        let mut skills = Vec::with_capacity(MODELS.len());
        for (mi, spec) in MODELS.iter().enumerate() {
            let mut per_ds = Vec::with_capacity(DATASETS.len());
            for (di, ds) in DATASETS.iter().enumerate() {
                let mut rng = root.fork((mi * 64 + di) as u64);
                let base = spec.skill_on(ds);
                let topics = (0..TOPICS_PER_DATASET)
                    .map(|_| (base + 0.12 * rng.normal()).clamp(0.02, 0.98))
                    .collect();
                per_ds.push(topics);
            }
            skills.push(per_ds);
        }
        SkillTable { skills }
    }

    pub fn skill(&self, model: usize, dataset: usize, topic: usize) -> f64 {
        self.skills[model][dataset][topic]
    }
}

/// Generate one prompt for (dataset, topic).
fn gen_prompt(rng: &mut Rng, dataset: usize, topic: usize) -> String {
    let (_, prefixes, suffixes) = PREFIXES[dataset];
    let keywords = TOPIC_KEYWORDS[dataset][topic];
    let mut text = String::new();
    text.push_str(*rng.choose(prefixes));
    // 2-3 topic keywords anchor the cluster
    let n_kw = 2 + rng.below(2);
    for &i in rng.sample_indices(keywords.len(), n_kw).iter() {
        text.push(' ');
        text.push_str(keywords[i]);
    }
    // 2-4 shared filler words + 2-4 prompt-specific pseudo-words
    for _ in 0..(2 + rng.below(3)) {
        text.push(' ');
        text.push_str(*rng.choose(FILLERS));
    }
    for _ in 0..(2 + rng.below(3)) {
        text.push(' ');
        let w = gibberish(rng);
        text.push_str(&w);
    }
    text.push(' ');
    text.push_str(*rng.choose(suffixes));
    text
}

/// Draw the observed quality of `spec` on a sample.
fn draw_quality(
    rng: &mut Rng,
    spec: &ModelSpec,
    skill: f64,
    difficulty: f64,
    binary: bool,
) -> f32 {
    let _ = spec;
    let p = (skill + 0.45 - 0.90 * difficulty + 0.05 * rng.normal()).clamp(0.0, 1.0);
    if binary {
        if rng.chance(p) {
            1.0
        } else {
            0.0
        }
    } else {
        (p + 0.10 * rng.normal()).clamp(0.0, 1.0) as f32
    }
}

/// Draw the observed $ cost of `spec` on one query.
fn draw_cost(rng: &mut Rng, spec: &ModelSpec) -> f32 {
    let sigma = 0.30;
    let mu = spec.mean_tokens.ln() - sigma * sigma / 2.0;
    let tokens = rng.log_normal(mu, sigma);
    (spec.price_per_mtok * tokens / 1e6) as f32
}

/// Generate the full benchmark from `params`.
pub fn generate(params: &DataParams) -> Benchmark {
    let skill_table = SkillTable::generate(params.seed);
    let mut root = Rng::with_stream(params.seed, 0xBE7C);
    let n_models = MODELS.len();

    let mut splits = Vec::with_capacity(DATASETS.len());
    for (di, ds_name) in DATASETS.iter().enumerate() {
        let binary = *ds_name != "mt-bench";
        let mut rng = root.fork(di as u64 + 1);

        // --- samples ---
        let mut samples = Vec::with_capacity(params.per_dataset);
        for _ in 0..params.per_dataset {
            let topic = rng.below(TOPICS_PER_DATASET);
            let text = gen_prompt(&mut rng, di, topic);
            // difficulty: uniform, wide — unpredictable from the prompt text
            let difficulty = rng.f64();
            let mut quality = Vec::with_capacity(n_models);
            let mut cost = Vec::with_capacity(n_models);
            for (mi, spec) in MODELS.iter().enumerate() {
                let skill = skill_table.skill(mi, di, topic);
                quality.push(draw_quality(&mut rng, spec, skill, difficulty, binary));
                cost.push(draw_cost(&mut rng, spec));
            }
            samples.push(Sample { dataset: di, topic, text, difficulty, quality, cost });
        }
        rng.shuffle(&mut samples);

        // --- split ---
        let n_train = ((samples.len() as f64) * params.train_fraction).round() as usize;
        let test = samples.split_off(n_train);
        let train = samples;

        // --- pairwise feedback over train, in stream order ---
        let mut feedback = Vec::with_capacity(train.len() * params.comparisons_per_prompt);
        for (si, s) in train.iter().enumerate() {
            for _ in 0..params.comparisons_per_prompt {
                let a = rng.below(n_models);
                let mut b = rng.below(n_models - 1);
                if b >= a {
                    b += 1;
                }
                let outcome = outcome_from_quality(s.quality[a], s.quality[b]);
                feedback.push(FeedbackRecord {
                    sample: si,
                    comparison: Comparison { a, b, outcome },
                });
            }
        }

        splits.push(DatasetSplit { dataset: di, train, test, feedback });
    }
    Benchmark { splits }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routerbench::models::model_index;

    fn small_params() -> DataParams {
        DataParams { seed: 42, per_dataset: 200, train_fraction: 0.7, comparisons_per_prompt: 3 }
    }

    #[test]
    fn static_tables_consistent() {
        assert_eq!(PREFIXES.len(), DATASETS.len());
        assert_eq!(TOPIC_KEYWORDS.len(), DATASETS.len());
        for (di, (name, prefixes, suffixes)) in PREFIXES.iter().enumerate() {
            assert_eq!(*name, DATASETS[di]);
            assert!(!prefixes.is_empty() && !suffixes.is_empty());
            assert_eq!(TOPIC_KEYWORDS[di].len(), TOPICS_PER_DATASET);
        }
    }

    #[test]
    fn deterministic() {
        let a = generate(&small_params());
        let b = generate(&small_params());
        assert_eq!(a.splits[0].train[0].text, b.splits[0].train[0].text);
        assert_eq!(a.splits[3].test[5].quality, b.splits[3].test[5].quality);
        assert_eq!(a.splits[6].feedback[17], b.splits[6].feedback[17]);
    }

    #[test]
    fn seed_changes_output() {
        let a = generate(&small_params());
        let mut p = small_params();
        p.seed = 43;
        let b = generate(&p);
        assert_ne!(a.splits[0].train[0].text, b.splits[0].train[0].text);
    }

    #[test]
    fn split_sizes_respect_fraction() {
        let b = generate(&small_params());
        for s in &b.splits {
            assert_eq!(s.train.len(), 140);
            assert_eq!(s.test.len(), 60);
            assert_eq!(s.feedback.len(), 140 * 3);
        }
    }

    #[test]
    fn qualities_and_costs_in_range() {
        let b = generate(&small_params());
        for s in &b.splits {
            for smp in s.train.iter().chain(&s.test) {
                assert_eq!(smp.quality.len(), MODELS.len());
                for &q in &smp.quality {
                    assert!((0.0..=1.0).contains(&q));
                }
                for (&c, spec) in smp.cost.iter().zip(MODELS) {
                    assert!(c > 0.0);
                    // within ~5x of expected cost (log-normal tail)
                    assert!((c as f64) < spec.expected_cost() * 6.0);
                }
            }
        }
    }

    #[test]
    fn binary_datasets_binary_quality() {
        let b = generate(&small_params());
        for s in &b.splits {
            if DATASETS[s.dataset] == "mt-bench" {
                continue;
            }
            for smp in &s.train {
                for &q in &smp.quality {
                    assert!(q == 0.0 || q == 1.0);
                }
            }
        }
    }

    #[test]
    fn feedback_outcomes_consistent_with_quality() {
        let b = generate(&small_params());
        for s in &b.splits {
            for f in &s.feedback {
                let smp = &s.train[f.sample];
                let expect =
                    outcome_from_quality(smp.quality[f.comparison.a], smp.quality[f.comparison.b]);
                assert_eq!(f.comparison.outcome, expect);
            }
        }
    }

    #[test]
    fn gpt4_beats_llama13b_on_average() {
        let b = generate(&small_params());
        let g = model_index("gpt-4").unwrap();
        let l = model_index("llama-2-13b-chat").unwrap();
        let (mut qg, mut ql, mut n) = (0.0f64, 0.0f64, 0);
        for s in &b.splits {
            for smp in &s.train {
                qg += smp.quality[g] as f64;
                ql += smp.quality[l] as f64;
                n += 1;
            }
        }
        assert!(qg / n as f64 > ql / n as f64 + 0.15);
    }

    #[test]
    fn code_llama_specialist_on_mbpp() {
        let b = generate(&small_params());
        let cl = model_index("code-llama-34b").unwrap();
        let mbpp = b.split("mbpp").unwrap();
        let mmlu = b.split("mmlu").unwrap();
        let mean = |s: &[Sample]| {
            s.iter().map(|x| x.quality[cl] as f64).sum::<f64>() / s.len() as f64
        };
        assert!(mean(&mbpp.train) > mean(&mmlu.train) + 0.15);
    }

    #[test]
    fn topic_skills_vary_within_dataset() {
        // Eagle-Local's signal: per-topic spread must exist.
        let t = SkillTable::generate(7);
        let mut any_spread = false;
        for m in 0..MODELS.len() {
            for d in 0..DATASETS.len() {
                let skills: Vec<f64> =
                    (0..TOPICS_PER_DATASET).map(|k| t.skill(m, d, k)).collect();
                let max = skills.iter().cloned().fold(f64::MIN, f64::max);
                let min = skills.iter().cloned().fold(f64::MAX, f64::min);
                if max - min > 0.15 {
                    any_spread = true;
                }
            }
        }
        assert!(any_spread);
    }

    #[test]
    fn same_topic_prompts_share_tokens() {
        let params = small_params();
        let b = generate(&params);
        let split = &b.splits[0];
        // group by topic; same-topic pairs share at least one keyword token
        let kw: Vec<Vec<&str>> =
            TOPIC_KEYWORDS[0].iter().map(|t| t.to_vec()).collect();
        for s in split.train.iter().take(50) {
            let hits = kw[s.topic].iter().filter(|k| s.text.contains(**k)).count();
            assert!(hits >= 2, "prompt missing topic anchors: {}", s.text);
        }
    }

    #[test]
    fn prompt_fits_tokenizer_seq_len() {
        let b = generate(&small_params());
        for s in &b.splits {
            for smp in s.train.iter().take(20) {
                let t = crate::tokenizer::tokenize_default(&smp.text);
                assert!(!t.is_empty());
                assert!(t.len() <= crate::tokenizer::SEQ_LEN);
            }
        }
    }
}
