//! Synthetic RouterBench substrate.
//!
//! The paper evaluates on the RouterBench dataset [Hu et al. 2024]: 7
//! public benchmarks, 11 LLMs, per-sample quality and cost for every
//! (prompt, model) pair. That dataset (and the authors' stella embeddings
//! of it) is not available offline, so this module regenerates its
//! *statistics* (DESIGN.md §Substitutions):
//!
//! - 7 datasets with templated prompts that cluster per (dataset, topic)
//!   in embedding space,
//! - 11 models with latent per-(model, dataset, topic) skills — overall
//!   ability ordering and specialist structure mirroring the real roster,
//! - per-sample binary/continuous quality draws and $ costs (price x
//!   log-normal token count),
//! - pairwise feedback records derived from quality comparisons — the only
//!   supervision Eagle sees (baselines also get the quality labels, as
//!   RouterBench's regression formulation does).
//!
//! Everything is deterministic given `DataParams::seed`.

pub mod gen;
pub mod models;

use crate::elo::{Comparison, Outcome};

/// The seven RouterBench datasets.
pub const DATASETS: &[&str] = &[
    "mmlu",
    "hellaswag",
    "gsm8k",
    "arc-challenge",
    "winogrande",
    "mbpp",
    "mt-bench",
];

/// Topics per dataset (sub-domains within which model skills vary — the
/// structure Eagle-Local exploits).
pub const TOPICS_PER_DATASET: usize = 8;

/// One benchmark prompt with per-model ground truth.
#[derive(Debug, Clone)]
pub struct Sample {
    /// Dataset index into [`DATASETS`].
    pub dataset: usize,
    /// Topic index within the dataset.
    pub topic: usize,
    /// Prompt text (templated; embeds near same-topic prompts).
    pub text: String,
    /// Latent difficulty in [0,1].
    pub difficulty: f64,
    /// Observed response quality per model in [0,1].
    pub quality: Vec<f32>,
    /// Observed $ cost per model.
    pub cost: Vec<f32>,
}

impl Sample {
    /// Best achievable quality over all models (oracle).
    pub fn oracle_quality(&self) -> f32 {
        self.quality.iter().cloned().fold(0.0, f32::max)
    }
}

/// A pairwise feedback record tied to a prompt (what users give Eagle).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FeedbackRecord {
    /// Index into the owning split's sample vector.
    pub sample: usize,
    pub comparison: Comparison,
}

/// One dataset's train/test split.
#[derive(Debug, Clone)]
pub struct DatasetSplit {
    /// Dataset index into [`DATASETS`].
    pub dataset: usize,
    pub train: Vec<Sample>,
    pub test: Vec<Sample>,
    /// Pairwise feedback over `train` samples, in collection order
    /// (prefixes of this stream define the 70%/85%/100% online stages).
    pub feedback: Vec<FeedbackRecord>,
}

/// The full synthetic benchmark.
#[derive(Debug, Clone)]
pub struct Benchmark {
    pub splits: Vec<DatasetSplit>,
}

impl Benchmark {
    pub fn split(&self, dataset_name: &str) -> Option<&DatasetSplit> {
        let idx = DATASETS.iter().position(|d| *d == dataset_name)?;
        self.splits.iter().find(|s| s.dataset == idx)
    }

    /// Total number of train samples across datasets.
    pub fn train_len(&self) -> usize {
        self.splits.iter().map(|s| s.train.len()).sum()
    }

    pub fn test_len(&self) -> usize {
        self.splits.iter().map(|s| s.test.len()).sum()
    }
}

/// Derive a pairwise outcome from two observed qualities.
pub fn outcome_from_quality(qa: f32, qb: f32) -> Outcome {
    if (qa - qb).abs() < 1e-6 {
        Outcome::Draw
    } else if qa > qb {
        Outcome::WinA
    } else {
        Outcome::WinB
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seven_datasets() {
        assert_eq!(DATASETS.len(), 7);
    }

    #[test]
    fn outcome_rules() {
        assert_eq!(outcome_from_quality(1.0, 0.0), Outcome::WinA);
        assert_eq!(outcome_from_quality(0.0, 1.0), Outcome::WinB);
        assert_eq!(outcome_from_quality(0.5, 0.5), Outcome::Draw);
    }

    #[test]
    fn oracle_quality_is_max() {
        let s = Sample {
            dataset: 0,
            topic: 0,
            text: "x".into(),
            difficulty: 0.5,
            quality: vec![0.2, 0.9, 0.4],
            cost: vec![0.1; 3],
        };
        assert_eq!(s.oracle_quality(), 0.9);
    }
}
