//! The model pool: 11 LLMs mirroring RouterBench's roster in capability
//! ordering and cost spread (DESIGN.md §Substitutions).
//!
//! Prices are blended $/1M tokens in the ballpark of the public 2024 price
//! sheets; `general` is the latent overall strength in [0,1];
//! `dataset_mods` are per-dataset latent skill adjustments capturing the
//! specialist structure the paper's routers exploit (code models good at
//! MBPP, math-tuned models at GSM8K, ...).

#[cfg(test)]
use super::DATASETS;

/// Static description of one candidate LLM.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: &'static str,
    /// Blended price, $ per 1M tokens.
    pub price_per_mtok: f64,
    /// Mean total tokens (prompt + completion) this model spends per query
    /// (verbosity differs across models).
    pub mean_tokens: f64,
    /// Latent general ability in [0, 1].
    pub general: f64,
    /// (dataset name, additive skill modifier).
    pub dataset_mods: &'static [(&'static str, f64)],
}

impl ModelSpec {
    /// Latent skill on a dataset (before per-topic variation).
    pub fn skill_on(&self, dataset: &str) -> f64 {
        let m = self
            .dataset_mods
            .iter()
            .find(|(d, _)| *d == dataset)
            .map(|(_, v)| *v)
            .unwrap_or(0.0);
        (self.general + m).clamp(0.02, 0.98)
    }

    /// Expected $ cost of one query.
    pub fn expected_cost(&self) -> f64 {
        self.price_per_mtok * self.mean_tokens / 1e6
    }
}

/// The 11-model pool.
pub const MODELS: &[ModelSpec] = &[
    ModelSpec {
        name: "gpt-4",
        price_per_mtok: 37.5,
        mean_tokens: 820.0,
        general: 0.90,
        dataset_mods: &[("mbpp", 0.04), ("gsm8k", 0.05), ("mt-bench", 0.06)],
    },
    ModelSpec {
        name: "gpt-3.5-turbo",
        price_per_mtok: 1.5,
        mean_tokens: 700.0,
        general: 0.70,
        dataset_mods: &[("gsm8k", 0.02), ("hellaswag", -0.04)],
    },
    ModelSpec {
        name: "claude-v2",
        price_per_mtok: 24.0,
        mean_tokens: 900.0,
        general: 0.85,
        dataset_mods: &[("mt-bench", 0.05), ("arc-challenge", 0.03), ("mbpp", -0.05)],
    },
    ModelSpec {
        name: "claude-v1",
        price_per_mtok: 16.0,
        mean_tokens: 850.0,
        general: 0.78,
        dataset_mods: &[("winogrande", 0.04), ("mbpp", -0.06)],
    },
    ModelSpec {
        name: "claude-instant-v1",
        price_per_mtok: 1.6,
        mean_tokens: 750.0,
        general: 0.66,
        dataset_mods: &[("hellaswag", 0.04), ("gsm8k", -0.08)],
    },
    ModelSpec {
        name: "llama-2-70b-chat",
        price_per_mtok: 1.0,
        mean_tokens: 800.0,
        general: 0.60,
        dataset_mods: &[("winogrande", 0.05), ("mbpp", -0.12), ("mt-bench", 0.02)],
    },
    ModelSpec {
        name: "llama-2-13b-chat",
        price_per_mtok: 0.3,
        mean_tokens: 760.0,
        general: 0.45,
        dataset_mods: &[("hellaswag", 0.05), ("gsm8k", -0.12)],
    },
    ModelSpec {
        name: "mixtral-8x7b-chat",
        price_per_mtok: 0.6,
        mean_tokens: 780.0,
        general: 0.68,
        dataset_mods: &[("gsm8k", 0.08), ("mmlu", 0.04), ("mt-bench", -0.03)],
    },
    ModelSpec {
        name: "mistral-7b-chat",
        price_per_mtok: 0.2,
        mean_tokens: 720.0,
        general: 0.50,
        dataset_mods: &[("arc-challenge", 0.04), ("mbpp", -0.08)],
    },
    ModelSpec {
        name: "wizardlm-13b",
        price_per_mtok: 0.3,
        mean_tokens: 880.0,
        general: 0.47,
        dataset_mods: &[("mt-bench", 0.08), ("gsm8k", -0.10), ("mmlu", -0.04)],
    },
    ModelSpec {
        name: "code-llama-34b",
        price_per_mtok: 0.8,
        mean_tokens: 640.0,
        general: 0.52,
        dataset_mods: &[("mbpp", 0.30), ("gsm8k", 0.08), ("mt-bench", -0.10), ("winogrande", -0.08)],
    },
];

/// Number of models in the pool.
pub fn n_models() -> usize {
    MODELS.len()
}

/// Index of a model by name.
pub fn model_index(name: &str) -> Option<usize> {
    MODELS.iter().position(|m| m.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eleven_models_like_routerbench() {
        assert_eq!(MODELS.len(), 11);
    }

    #[test]
    fn names_unique() {
        let mut names: Vec<_> = MODELS.iter().map(|m| m.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), MODELS.len());
    }

    #[test]
    fn dataset_mods_reference_real_datasets() {
        for m in MODELS {
            for (d, _) in m.dataset_mods {
                assert!(DATASETS.contains(d), "{} references unknown dataset {d}", m.name);
            }
        }
    }

    #[test]
    fn cost_spread_covers_two_orders_of_magnitude() {
        let costs: Vec<f64> = MODELS.iter().map(|m| m.expected_cost()).collect();
        let max = costs.iter().cloned().fold(0.0, f64::max);
        let min = costs.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min > 50.0, "spread {}x", max / min);
    }

    #[test]
    fn gpt4_strongest_overall_and_most_expensive() {
        let gpt4 = &MODELS[model_index("gpt-4").unwrap()];
        for m in MODELS {
            assert!(gpt4.general >= m.general);
            assert!(gpt4.expected_cost() >= m.expected_cost());
        }
    }

    #[test]
    fn code_llama_best_at_mbpp_per_dollar_class() {
        let cl = &MODELS[model_index("code-llama-34b").unwrap()];
        assert!(cl.skill_on("mbpp") > cl.skill_on("mmlu") + 0.2);
    }

    #[test]
    fn skill_clamped() {
        for m in MODELS {
            for d in DATASETS {
                let s = m.skill_on(d);
                assert!((0.0..=1.0).contains(&s));
            }
        }
    }
}
