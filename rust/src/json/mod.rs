//! Minimal JSON codec (serde is unavailable offline — DESIGN.md
//! §Substitutions). Covers the full JSON grammar we produce/consume:
//! artifacts/manifest.json, artifacts/golden.json, dataset files, the
//! serving wire protocol, and state snapshots.
//!
//! Numbers are stored as f64 (JSON's native model); [`Value::as_usize`]
//! guards integral reads.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use a BTreeMap so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; returns Null for missing keys on non-objects.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup.
    pub fn at(&self, idx: usize) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Arr(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write_json(&mut out);
        out
    }

    fn write_json(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(n) => write_num(*n, out),
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_json(out);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write_json(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; emit null (matches python json with allow_nan
        // disabled semantics closest to safety).
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders.
pub fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Value>) -> Value {
    Value::Arr(items)
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn str_v(s: &str) -> Value {
    Value::Str(s.to_string())
}

pub fn f32_arr(xs: &[f32]) -> Value {
    Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
}

/// Parse a JSON document. Returns an error with byte offset on failure.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

/// JSON parse error with byte position.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Value::Null),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected byte '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        if (0xD800..0xDC00).contains(&cp) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else {
                            s.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode UTF-8 multibyte sequences from the raw input.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").at(2).get("b"), &Value::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{1F600}"));
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = parse("\"caf\u{e9} \u{1F600}\"").unwrap();
        assert_eq!(v.as_str(), Some("caf\u{e9} \u{1F600}"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn parse_whitespace_tolerant() {
        let v = parse(" {\n\t\"k\" :\r [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("k").at(1).as_f64(), Some(2.0));
    }

    #[test]
    fn serialize_roundtrip_handwritten() {
        let v = obj(vec![
            ("name", str_v("eagle")),
            ("n", num(20.0)),
            ("scores", f32_arr(&[1.5, -2.0])),
            ("nested", obj(vec![("ok", Value::Bool(true))])),
        ]);
        let text = v.to_json();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn serialize_escapes() {
        let v = Value::Str("a\"b\\c\nd\u{0001}".into());
        assert_eq!(parse(&v.to_json()).unwrap(), v);
    }

    #[test]
    fn integers_serialized_without_fraction() {
        assert_eq!(num(3.0).to_json(), "3");
        assert_eq!(num(3.25).to_json(), "3.25");
    }

    #[test]
    fn nonfinite_serializes_as_null() {
        assert_eq!(num(f64::NAN).to_json(), "null");
        assert_eq!(num(f64::INFINITY).to_json(), "null");
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(num(5.0).as_usize(), Some(5));
        assert_eq!(num(5.5).as_usize(), None);
        assert_eq!(num(-1.0).as_usize(), None);
        assert_eq!(str_v("5").as_usize(), None);
    }

    #[test]
    fn accessor_defaults_on_missing() {
        let v = parse("{}").unwrap();
        assert!(v.get("missing").is_null());
        assert!(v.at(3).is_null());
    }

    #[test]
    fn prop_roundtrip_random_values() {
        prop::check("json roundtrip", 200, |rng| {
            let v = random_value(rng, 3);
            let text = v.to_json();
            let back = parse(&text).map_err(|e| e.to_string())?;
            prop::assert_prop(values_close(&v, &back), "roundtrip mismatch")
        });
    }

    fn random_value(rng: &mut crate::util::Rng, depth: usize) -> Value {
        let choice = if depth == 0 { rng.below(4) } else { rng.below(6) };
        match choice {
            0 => Value::Null,
            1 => Value::Bool(rng.chance(0.5)),
            2 => Value::Num((rng.f64() * 2000.0 - 1000.0 * rng.f64()).round() / 8.0),
            3 => Value::Str(prop::sentence(rng, 4)),
            4 => Value::Arr((0..rng.below(4)).map(|_| random_value(rng, depth - 1)).collect()),
            _ => Value::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }

    fn values_close(a: &Value, b: &Value) -> bool {
        match (a, b) {
            (Value::Num(x), Value::Num(y)) => (x - y).abs() < 1e-9,
            (Value::Arr(x), Value::Arr(y)) => {
                x.len() == y.len() && x.iter().zip(y).all(|(p, q)| values_close(p, q))
            }
            (Value::Obj(x), Value::Obj(y)) => {
                x.len() == y.len()
                    && x.iter().zip(y).all(|((k1, v1), (k2, v2))| k1 == k2 && values_close(v1, v2))
            }
            _ => a == b,
        }
    }
}
