//! Segmented append-only storage with O(1) freeze — the vector-index half
//! of snapshot routing (see [`crate::coordinator::snapshot`]).
//!
//! The single-writer ingest side owns a [`SegmentStore`]: new vectors land
//! in a mutable *pending* segment; [`SegmentStore::freeze`] seals pending
//! into an immutable [`Segment`] behind an `Arc` and hands out a
//! [`FrozenView`] — a list of `Arc<Segment>` clones plus a visible length.
//! Publishing a snapshot therefore costs O(records since last publish)
//! to seal plus a handful of refcount bumps, never a copy of the corpus.
//!
//! Sealed segments are merged binary-counter style (merge the last two
//! while the newer one is at least as large) so a store of n vectors holds
//! O(log n) segments and each vector is copied O(log n) times total —
//! scans stay cache-friendly without ever blocking readers, who keep their
//! own `Arc`s to the pre-merge segments.
//!
//! Entry ids are global insertion indices; segment order is insertion
//! order, and a [`FrozenView`] scan pushes candidates in ascending id
//! order, so search results (including tie-breaks) are bit-identical to a
//! [`super::flat::FlatStore`] holding the same vectors.

use std::sync::Arc;

use super::kernel;
use super::topk::TopK;
use super::{BatchTopK, Feedback, Hit, ReadIndex, VectorIndex};

/// Locate a global id among sealed segments: `(segment index, local
/// index)`. `bases` holds each segment's first global id, ascending;
/// callers guarantee `id` falls inside a sealed segment.
fn locate_sealed(bases: &[u32], id: u32) -> (usize, usize) {
    let si = bases.partition_point(|&b| b <= id) - 1;
    (si, (id - bases[si]) as usize)
}

/// Backing storage for a segment's row-major vector slab: either an owned
/// heap allocation (live ingest, merges) or a zero-copy view into an
/// mmap'ed v2 segment file (durable recovery / follower catch-up). Readers
/// only ever see `&[f32]`, so scans, quantization sidecars, and merges are
/// agnostic to where the floats live.
pub(crate) enum Slab {
    Owned(Vec<f32>),
    Mapped(crate::mmap::SlabRef),
}

impl Slab {
    pub(crate) fn as_f32s(&self) -> &[f32] {
        match self {
            Slab::Owned(v) => v,
            Slab::Mapped(m) => m.as_f32s(),
        }
    }
}

impl std::fmt::Debug for Slab {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Slab::Owned(v) => write!(f, "Slab::Owned({} floats)", v.len()),
            Slab::Mapped(m) => write!(f, "Slab::Mapped({} floats)", m.len()),
        }
    }
}

/// An immutable block of vectors + payloads. Never mutated once sealed.
#[derive(Debug)]
pub struct Segment {
    dim: usize,
    data: Slab,
    payloads: Vec<Feedback>,
}

impl Segment {
    fn new(dim: usize) -> Self {
        Segment { dim, data: Slab::Owned(Vec::new()), payloads: Vec::new() }
    }

    fn with_capacity(dim: usize, capacity: usize) -> Self {
        Segment {
            dim,
            data: Slab::Owned(Vec::with_capacity(capacity * dim)),
            payloads: Vec::with_capacity(capacity),
        }
    }

    pub fn len(&self) -> usize {
        self.payloads.len()
    }

    pub fn is_empty(&self) -> bool {
        self.payloads.is_empty()
    }

    /// Mutable access to the owned float buffer. Only pending segments and
    /// in-progress merges are ever written to, and those are owned by
    /// construction — mapped slabs are sealed the moment they exist.
    fn data_mut(&mut self) -> &mut Vec<f32> {
        match &mut self.data {
            Slab::Owned(v) => v,
            Slab::Mapped(_) => unreachable!("mapped segments are never mutated"),
        }
    }

    fn push(&mut self, vector: &[f32], feedback: Feedback) {
        debug_assert_eq!(vector.len(), self.dim);
        self.data_mut().extend_from_slice(vector);
        self.payloads.push(feedback);
    }

    /// Concatenate another segment's rows onto this (owned) one.
    fn extend_from(&mut self, other: &Segment) {
        self.data_mut().extend_from_slice(other.vectors());
        self.payloads.extend_from_slice(&other.payloads);
    }

    fn row(&self, i: usize) -> &[f32] {
        &self.data.as_f32s()[i * self.dim..(i + 1) * self.dim]
    }

    /// The row-major vector slab (the SQ8 sidecar encoder reads sealed
    /// segments through this).
    pub(crate) fn vectors(&self) -> &[f32] {
        self.data.as_f32s()
    }

    /// Scan this segment into `topk`, offsetting local indices by `base`.
    pub(crate) fn scan_into(&self, query: &[f32], base: u32, topk: &mut TopK) {
        // resolve the kernel dispatch once for the whole scan
        let dot = kernel::dot_fn();
        for i in 0..self.payloads.len() {
            topk.push(base + i as u32, dot(self.row(i), query));
        }
    }

    /// Scan this segment for a whole query block through the blocked
    /// kernel, pushing `(base + row, score)` into each query's selector.
    /// Bit-identical hits to [`Segment::scan_into`] per query.
    pub(crate) fn scan_block_into(
        &self,
        queries: &[&[f32]],
        base: u32,
        topks: &mut [TopK],
        tile: &mut Vec<f32>,
    ) {
        kernel::scan_rows_into(queries, self.dim, self.data.as_f32s(), base, topks, tile);
    }
}

/// An immutable, cheaply-clonable view over a prefix of a [`SegmentStore`].
///
/// Cloning copies `O(segments)` `Arc`s. Safe to share across threads and
/// to keep alive across writer merges — the `Arc`s pin the exact segments
/// this view was built from.
#[derive(Debug, Clone)]
pub struct FrozenView {
    dim: usize,
    len: usize,
    segments: Vec<Arc<Segment>>,
    /// Global id of the first entry of each segment (parallel to
    /// `segments`); ascending.
    bases: Vec<u32>,
}

impl FrozenView {
    /// An empty view (what a cold-started router publishes first).
    pub fn empty(dim: usize) -> Self {
        FrozenView { dim, len: 0, segments: Vec::new(), bases: Vec::new() }
    }

    pub fn segment_count(&self) -> usize {
        self.segments.len()
    }

    /// The sealed segments this view pins, in id order (the SQ8 view
    /// builds per-segment quantized sidecars parallel to this list).
    pub(crate) fn segments(&self) -> &[Arc<Segment>] {
        &self.segments
    }

    /// Global id of each segment's first entry (parallel to
    /// [`FrozenView::segments`]).
    pub(crate) fn bases(&self) -> &[u32] {
        &self.bases
    }

    /// Locate (segment index, local index) for a global id.
    fn locate(&self, id: u32) -> (usize, usize) {
        debug_assert!((id as usize) < self.len, "id {id} out of view");
        locate_sealed(&self.bases, id)
    }

    /// Blocked multi-query scan of every segment, ids offset by
    /// `id_offset` (the IVF view scans its tail this way, offset past the
    /// core's id space). Pushes into the per-query selectors in ascending
    /// id order — bit-identical hits to per-query [`FrozenView::search`].
    pub(crate) fn scan_segments_into(
        &self,
        queries: &[&[f32]],
        id_offset: u32,
        topks: &mut [TopK],
        tile: &mut Vec<f32>,
    ) {
        for (seg, &base) in self.segments.iter().zip(&self.bases) {
            seg.scan_block_into(queries, id_offset + base, topks, tile);
        }
    }
}

impl ReadIndex for FrozenView {
    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.len
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        assert_eq!(query.len(), self.dim, "query dim mismatch");
        let mut topk = TopK::new(k);
        for (seg, &base) in self.segments.iter().zip(&self.bases) {
            seg.scan_into(query, base, &mut topk);
        }
        topk.into_sorted()
            .into_iter()
            .map(|(id, score)| Hit { id, score })
            .collect()
    }

    fn search_batch_into(&self, queries: &[&[f32]], k: usize, acc: &mut BatchTopK) {
        for q in queries {
            assert_eq!(q.len(), self.dim, "query dim mismatch");
        }
        acc.begin(queries.len(), k);
        let (topks, tile) = acc.parts_mut();
        self.scan_segments_into(queries, 0, topks, tile);
    }

    fn feedback(&self, id: u32) -> &Feedback {
        let (si, li) = self.locate(id);
        &self.segments[si].payloads[li]
    }

    fn vector(&self, id: u32) -> &[f32] {
        let (si, li) = self.locate(id);
        self.segments[si].row(li)
    }
}

/// The writer-owned segmented store. Implements [`VectorIndex`] so it can
/// sit inside an `EagleRouter` unchanged; additionally supports
/// [`SegmentStore::freeze`] for snapshot publication.
#[derive(Debug)]
pub struct SegmentStore {
    dim: usize,
    sealed: Vec<Arc<Segment>>,
    bases: Vec<u32>,
    sealed_len: usize,
    pending: Segment,
}

impl SegmentStore {
    pub fn new(dim: usize) -> Self {
        assert!(dim > 0, "dim must be positive");
        SegmentStore {
            dim,
            sealed: Vec::new(),
            bases: Vec::new(),
            sealed_len: 0,
            pending: Segment::new(dim),
        }
    }

    /// Copy an existing flat store (snapshot restore / server bring-up).
    pub fn from_flat(flat: &super::flat::FlatStore) -> Self {
        let dim = flat.dim();
        let n = flat.len();
        let mut seg = Segment::with_capacity(dim, n);
        for id in 0..n as u32 {
            seg.push(flat.vector(id), flat.feedback(id).clone());
        }
        let mut store = SegmentStore::new(dim);
        if !seg.is_empty() {
            store.sealed_len = seg.len();
            store.bases.push(0);
            store.sealed.push(Arc::new(seg));
        }
        store
    }

    pub fn segment_count(&self) -> usize {
        self.sealed.len() + usize::from(!self.pending.is_empty())
    }

    /// Append one pre-sealed immutable block (durable-store recovery: each
    /// on-disk segment file lands as one in-memory sealed segment, so a
    /// restart costs one bulk copy per file instead of per-row inserts).
    /// Rows keep arrival order, so ids stay insertion indices. Must be
    /// called before any pending inserts — sealed ids precede pending ids.
    /// No merging happens here; the next [`SegmentStore::freeze`] compacts
    /// as usual.
    pub fn push_sealed_block<'a, I>(&mut self, rows: I)
    where
        I: IntoIterator<Item = (&'a [f32], Feedback)>,
    {
        assert!(
            self.pending.is_empty(),
            "sealed blocks must precede pending inserts"
        );
        let mut seg = Segment::new(self.dim);
        for (vector, feedback) in rows {
            seg.push(vector, feedback);
        }
        if seg.is_empty() {
            return;
        }
        self.bases.push(self.sealed_len as u32);
        self.sealed_len += seg.len();
        self.sealed.push(Arc::new(seg));
    }

    /// Append one pre-sealed block whose vectors already live in a [`Slab`]
    /// — for mapped v2 segment files this is the zero-copy restart path:
    /// the floats stay in the page cache and the store only takes payloads
    /// + an `Arc` on the mapping. Unlike
    /// [`SegmentStore::push_sealed_block`], pending inserts may precede the
    /// block (mixed-format catch-up interleaves log records and sealed
    /// segments); pending is sealed first so ids keep arrival order.
    pub(crate) fn push_block(&mut self, slab: Slab, payloads: Vec<Feedback>) {
        if payloads.is_empty() {
            return;
        }
        debug_assert_eq!(slab.as_f32s().len(), payloads.len() * self.dim);
        self.seal_pending();
        self.bases.push(self.sealed_len as u32);
        self.sealed_len += payloads.len();
        self.sealed.push(Arc::new(Segment { dim: self.dim, data: slab, payloads }));
    }

    fn seal_pending(&mut self) {
        if !self.pending.is_empty() {
            let seg = std::mem::replace(&mut self.pending, Segment::new(self.dim));
            self.bases.push(self.sealed_len as u32);
            self.sealed_len += seg.len();
            self.sealed.push(Arc::new(seg));
        }
    }

    /// Seal the pending segment (if any) and merge binary-counter style:
    /// while the newest sealed segment is at least as large as its
    /// predecessor, replace the pair with their concatenation. Keeps the
    /// segment count at O(log n) with O(log n) amortized copies per entry.
    /// Merging a mapped segment copies it into an owned one — exactly the
    /// moment its pages would stop being shareable anyway.
    fn seal_and_merge(&mut self) {
        self.seal_pending();
        while self.sealed.len() >= 2
            && self.sealed[self.sealed.len() - 1].len() >= self.sealed[self.sealed.len() - 2].len()
        {
            let newer = self.sealed.pop().unwrap();
            let older = self.sealed.pop().unwrap();
            self.bases.pop();
            let mut merged = Segment::with_capacity(self.dim, older.len() + newer.len());
            for seg in [&older, &newer] {
                merged.extend_from(seg);
            }
            self.sealed.push(Arc::new(merged));
        }
    }

    /// Freeze the current contents into an immutable view. O(pending) to
    /// seal + O(log n) `Arc` clones; the writer keeps appending afterwards
    /// without ever touching what the view pinned.
    pub fn freeze(&mut self) -> FrozenView {
        self.seal_and_merge();
        FrozenView {
            dim: self.dim,
            len: self.sealed_len,
            segments: self.sealed.clone(),
            bases: self.bases.clone(),
        }
    }
}

impl ReadIndex for SegmentStore {
    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.sealed_len + self.pending.len()
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        assert_eq!(query.len(), self.dim, "query dim mismatch");
        let mut topk = TopK::new(k);
        for (seg, &base) in self.sealed.iter().zip(&self.bases) {
            seg.scan_into(query, base, &mut topk);
        }
        self.pending.scan_into(query, self.sealed_len as u32, &mut topk);
        topk.into_sorted()
            .into_iter()
            .map(|(id, score)| Hit { id, score })
            .collect()
    }

    fn feedback(&self, id: u32) -> &Feedback {
        if (id as usize) >= self.sealed_len {
            return &self.pending.payloads[id as usize - self.sealed_len];
        }
        let (si, li) = locate_sealed(&self.bases, id);
        &self.sealed[si].payloads[li]
    }

    fn vector(&self, id: u32) -> &[f32] {
        if (id as usize) >= self.sealed_len {
            return self.pending.row(id as usize - self.sealed_len);
        }
        let (si, li) = locate_sealed(&self.bases, id);
        self.sealed[si].row(li)
    }
}

impl VectorIndex for SegmentStore {
    fn add(&mut self, vector: &[f32], feedback: Feedback) -> u32 {
        assert_eq!(vector.len(), self.dim, "vector dim mismatch");
        let id = self.len() as u32;
        self.pending.push(vector, feedback);
        id
    }
}

#[cfg(test)]
mod tests {
    use super::super::flat::FlatStore;
    use super::super::testutil::*;
    use super::*;
    use crate::util::{prop, Rng};

    /// Build a flat store and a segment store with identical contents,
    /// freezing the segment store every `freeze_every` inserts.
    fn twin_stores(
        rng: &mut Rng,
        n: usize,
        dim: usize,
        freeze_every: usize,
    ) -> (FlatStore, SegmentStore, Vec<FrozenView>) {
        let mut flat = FlatStore::new(dim);
        let mut seg = SegmentStore::new(dim);
        let mut views = Vec::new();
        for i in 0..n {
            let v = random_unit(rng, dim);
            flat.add(&v, dummy_feedback(i));
            seg.add(&v, dummy_feedback(i));
            if freeze_every > 0 && (i + 1) % freeze_every == 0 {
                views.push(seg.freeze());
            }
        }
        (flat, seg, views)
    }

    #[test]
    fn segment_store_matches_flat_exactly() {
        prop::check("segmented == flat", 40, |rng| {
            let dim = [4, 16, 64][rng.below(3)];
            let n = 1 + rng.below(400);
            let k = 1 + rng.below(30);
            let freeze_every = 1 + rng.below(50);
            let (flat, seg, _) = twin_stores(rng, n, dim, freeze_every);
            let q = random_unit(rng, dim);
            let a = flat.search(&q, k);
            let b = seg.search(&q, k);
            prop::assert_prop(a == b, "hit lists differ")
        });
    }

    #[test]
    fn frozen_view_matches_flat_prefix() {
        prop::check("frozen view == flat prefix", 30, |rng| {
            let dim = 16;
            let n = 50 + rng.below(300);
            let freeze_every = 1 + rng.below(40);
            let (flat, _, views) = twin_stores(rng, n, dim, freeze_every);
            let q = random_unit(rng, dim);
            for (vi, view) in views.iter().enumerate() {
                let visible = (vi + 1) * freeze_every;
                prop::assert_prop(view.len() == visible, "view length")?;
                // rebuild the prefix flat store for an exact comparison
                let mut prefix = FlatStore::new(dim);
                for id in 0..visible as u32 {
                    prefix.add(flat.vector(id), flat.feedback(id).clone());
                }
                let a = prefix.search(&q, 10);
                let b = view.search(&q, 10);
                prop::assert_prop(a == b, "prefix hit lists differ")?;
            }
            Ok(())
        });
    }

    #[test]
    fn frozen_view_batch_search_bit_identical_to_singles() {
        // the blocked multi-segment scan must retain exactly the hits of
        // per-query scans at every freeze granularity
        prop::check("frozen batch == singles", 25, |rng| {
            let dim = [8, 16, 64][rng.below(3)];
            let n = rng.below(500);
            let k = 1 + rng.below(25);
            let freeze_every = 1 + rng.below(60);
            let (_, mut seg, _) = twin_stores(rng, n, dim, freeze_every);
            let view = seg.freeze();
            let n_q = rng.below(10);
            let queries: Vec<Vec<f32>> = (0..n_q).map(|_| random_unit(rng, dim)).collect();
            let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
            let batch = view.search_batch(&qrefs, k);
            for (q, hits) in qrefs.iter().zip(&batch) {
                prop::assert_prop(hits == &view.search(q, k), "batch hits != single hits")?;
            }
            Ok(())
        });
    }

    #[test]
    fn views_survive_later_merges() {
        // a view taken early must keep returning its exact contents even
        // after the writer merges/compacts segments many times over
        let mut rng = Rng::new(7);
        let dim = 8;
        let mut seg = SegmentStore::new(dim);
        let mut vectors = Vec::new();
        for i in 0..32 {
            let v = random_unit(&mut rng, dim);
            seg.add(&v, dummy_feedback(i));
            vectors.push(v);
        }
        let early = seg.freeze();
        for i in 32..512 {
            seg.add(&random_unit(&mut rng, dim), dummy_feedback(i));
            if i % 17 == 0 {
                let _ = seg.freeze();
            }
        }
        assert_eq!(early.len(), 32);
        for (i, v) in vectors.iter().enumerate() {
            assert_eq!(early.vector(i as u32), v.as_slice());
            assert_eq!(early.feedback(i as u32), &dummy_feedback(i));
        }
    }

    #[test]
    fn merge_keeps_log_segments() {
        let mut rng = Rng::new(9);
        let mut seg = SegmentStore::new(4);
        for i in 0..4096 {
            seg.add(&random_unit(&mut rng, 4), dummy_feedback(i));
            if i % 3 == 0 {
                let _ = seg.freeze();
            }
        }
        let view = seg.freeze();
        assert_eq!(view.len(), 4096);
        // binary-counter merging: segment count stays logarithmic
        assert!(
            view.segment_count() <= 14,
            "{} segments for 4096 entries",
            view.segment_count()
        );
    }

    #[test]
    fn from_flat_roundtrip() {
        let mut rng = Rng::new(11);
        let mut flat = FlatStore::new(8);
        for i in 0..100 {
            flat.add(&random_unit(&mut rng, 8), dummy_feedback(i));
        }
        let mut seg = SegmentStore::from_flat(&flat);
        assert_eq!(seg.len(), 100);
        let q = random_unit(&mut rng, 8);
        assert_eq!(flat.search(&q, 7), seg.search(&q, 7));
        let view = seg.freeze();
        assert_eq!(view.search(&q, 7), flat.search(&q, 7));
    }

    #[test]
    fn empty_store_and_view() {
        let mut seg = SegmentStore::new(4);
        assert!(seg.is_empty());
        let view = seg.freeze();
        assert_eq!(view.len(), 0);
        assert!(view.search(&[1.0, 0.0, 0.0, 0.0], 5).is_empty());
        let empty = FrozenView::empty(4);
        assert!(empty.search(&[1.0, 0.0, 0.0, 0.0], 3).is_empty());
    }

    #[test]
    fn sealed_blocks_match_flat_and_keep_ids() {
        // the durable-recovery bulk path: pre-sealed blocks + pending
        // inserts must be indistinguishable from row-at-a-time adds
        let mut rng = Rng::new(17);
        let dim = 8;
        let mut flat = FlatStore::new(dim);
        let mut seg = SegmentStore::new(dim);
        let mut i = 0;
        for _ in 0..5 {
            let n = 3 + rng.below(20);
            let rows: Vec<(Vec<f32>, Feedback)> = (0..n)
                .map(|_| {
                    let v = random_unit(&mut rng, dim);
                    let fb = dummy_feedback(i);
                    i += 1;
                    (v, fb)
                })
                .collect();
            for (v, fb) in &rows {
                flat.add(v, fb.clone());
            }
            seg.push_sealed_block(rows.iter().map(|(v, fb)| (v.as_slice(), fb.clone())));
        }
        assert_eq!(seg.len(), flat.len());
        for _ in 0..7 {
            let v = random_unit(&mut rng, dim);
            flat.add(&v, dummy_feedback(i));
            seg.add(&v, dummy_feedback(i));
            i += 1;
        }
        let q = random_unit(&mut rng, dim);
        assert_eq!(flat.search(&q, 10), seg.search(&q, 10));
        for id in 0..flat.len() as u32 {
            assert_eq!(flat.vector(id), seg.vector(id));
            assert_eq!(flat.feedback(id), seg.feedback(id));
        }
        let view = seg.freeze();
        assert_eq!(view.search(&q, 10), flat.search(&q, 10));
        // an empty block is a no-op
        seg.push_sealed_block(std::iter::empty::<(&[f32], Feedback)>());
        assert_eq!(seg.len(), flat.len());
    }

    #[test]
    fn push_block_seals_pending_and_matches_flat() {
        // the mmap restart path: slab blocks may interleave with pending
        // row inserts (mixed v1/v2 manifests) and must stay bit-identical
        // to a flat store fed the same rows in the same order
        let mut rng = Rng::new(23);
        let dim = 8;
        let mut flat = FlatStore::new(dim);
        let mut seg = SegmentStore::new(dim);
        let mut i = 0;
        for round in 0..4 {
            for _ in 0..3 + rng.below(5) {
                let v = random_unit(&mut rng, dim);
                flat.add(&v, dummy_feedback(i));
                seg.add(&v, dummy_feedback(i));
                i += 1;
            }
            let n = 2 + rng.below(10);
            let mut slab = Vec::new();
            let mut payloads = Vec::new();
            for _ in 0..n {
                let v = random_unit(&mut rng, dim);
                flat.add(&v, dummy_feedback(i));
                slab.extend_from_slice(&v);
                payloads.push(dummy_feedback(i));
                i += 1;
            }
            seg.push_block(Slab::Owned(slab), payloads);
            if round % 2 == 1 {
                let _ = seg.freeze();
            }
        }
        assert_eq!(seg.len(), flat.len());
        let q = random_unit(&mut rng, dim);
        assert_eq!(flat.search(&q, 12), seg.search(&q, 12));
        for id in 0..flat.len() as u32 {
            assert_eq!(flat.vector(id), seg.vector(id));
            assert_eq!(flat.feedback(id), seg.feedback(id));
        }
        // empty blocks are a no-op
        seg.push_block(Slab::Owned(Vec::new()), Vec::new());
        assert_eq!(seg.len(), flat.len());
    }

    #[test]
    fn freeze_excludes_later_inserts() {
        let mut rng = Rng::new(13);
        let mut seg = SegmentStore::new(8);
        for i in 0..10 {
            seg.add(&random_unit(&mut rng, 8), dummy_feedback(i));
        }
        let view = seg.freeze();
        let probe = random_unit(&mut rng, 8);
        seg.add(&probe, dummy_feedback(99));
        assert_eq!(view.len(), 10);
        // the probe vector is its own nearest neighbor in the store but
        // must be invisible to the earlier view
        assert_eq!(seg.search(&probe, 1)[0].id, 10);
        assert!(view.search(&probe, 11).iter().all(|h| h.id < 10));
    }
}
