//! Bounded top-k selector over (index, score) pairs.
//!
//! A fixed-capacity binary min-heap on score: O(n log k) selection with no
//! per-candidate allocation — this sits inside the vector-scan hot loop.

/// Collects the k highest-scoring items.
#[derive(Debug, Clone)]
pub struct TopK {
    k: usize,
    // min-heap: heap[0] is the *worst* retained item
    heap: Vec<(f32, u32)>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        TopK { k, heap: Vec::with_capacity(k) }
    }

    /// Reset for reuse without freeing the buffer.
    pub fn clear(&mut self) {
        self.heap.clear();
    }

    /// Reset for reuse with a (possibly different) capacity, keeping the
    /// allocation — the batch scan path recycles selectors across batches.
    pub fn reset(&mut self, k: usize) {
        self.k = k;
        self.heap.clear();
        // len is 0 after the clear, so this guarantees capacity >= k
        self.heap.reserve(k);
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Current admission threshold: items scoring <= this (when full) are
    /// rejected without a heap operation.
    #[inline]
    pub fn threshold(&self) -> f32 {
        if self.heap.len() < self.k {
            f32::NEG_INFINITY
        } else {
            self.heap[0].0
        }
    }

    /// `a` is strictly worse than `b`: lower score, or equal score with a
    /// higher index (so ties resolve to the lowest indices, matching a
    /// stable sort by (score desc, index asc)).
    #[inline]
    fn worse(a: (f32, u32), b: (f32, u32)) -> bool {
        a.0 < b.0 || (a.0 == b.0 && a.1 > b.1)
    }

    /// Offer a candidate.
    #[inline]
    pub fn push(&mut self, index: u32, score: f32) {
        if self.k == 0 {
            return;
        }
        if self.heap.len() < self.k {
            self.heap.push((score, index));
            self.sift_up(self.heap.len() - 1);
        } else if Self::worse(self.heap[0], (score, index)) {
            self.heap[0] = (score, index);
            self.sift_down(0);
        }
    }

    /// Drain into a (index, score) vector sorted by descending score.
    /// Ties break by ascending index (deterministic).
    pub fn into_sorted(mut self) -> Vec<(u32, f32)> {
        self.heap
            .sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        self.heap.into_iter().map(|(s, i)| (i, s)).collect()
    }

    /// Sort retained items (descending score, ascending index — exactly
    /// [`TopK::into_sorted`]'s order) and visit each, leaving the selector
    /// empty for reuse. Allocation-free drain for the batch route path.
    pub fn drain_sorted(&mut self, mut f: impl FnMut(u32, f32)) {
        self.heap
            .sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        for (s, i) in self.heap.drain(..) {
            f(i, s);
        }
    }

    /// Visit every retained item in unspecified order and empty the
    /// selector. The SQ8 exact-rerank path drains its over-fetched
    /// candidate set this way: every candidate gets rescored by the exact
    /// kernel anyway, so the sort [`TopK::drain_sorted`] pays would be
    /// wasted work in the hot loop.
    pub fn drain(&mut self, mut f: impl FnMut(u32, f32)) {
        for (s, i) in self.heap.drain(..) {
            f(i, s);
        }
    }

    /// Sorted snapshot without consuming (allocates).
    pub fn sorted(&self) -> Vec<(u32, f32)> {
        self.clone().into_sorted()
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if Self::worse(self.heap[i], self.heap[parent]) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut worst = i;
            if l < n && Self::worse(self.heap[l], self.heap[worst]) {
                worst = l;
            }
            if r < n && Self::worse(self.heap[r], self.heap[worst]) {
                worst = r;
            }
            if worst == i {
                return;
            }
            self.heap.swap(i, worst);
            i = worst;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Rng};

    #[test]
    fn selects_top_k() {
        let mut t = TopK::new(3);
        for (i, s) in [(0u32, 1.0f32), (1, 5.0), (2, 3.0), (3, 4.0), (4, 2.0)] {
            t.push(i, s);
        }
        let out = t.into_sorted();
        assert_eq!(out, vec![(1, 5.0), (3, 4.0), (2, 3.0)]);
    }

    #[test]
    fn fewer_items_than_k() {
        let mut t = TopK::new(10);
        t.push(7, 0.5);
        assert_eq!(t.into_sorted(), vec![(7, 0.5)]);
    }

    #[test]
    fn k_zero() {
        let mut t = TopK::new(0);
        t.push(0, 1.0);
        assert!(t.into_sorted().is_empty());
    }

    #[test]
    fn tie_break_by_index() {
        let mut t = TopK::new(2);
        t.push(9, 1.0);
        t.push(3, 1.0);
        t.push(5, 1.0);
        let out = t.into_sorted();
        assert_eq!(out[0].0, 3);
    }

    #[test]
    fn threshold_tracks_worst() {
        let mut t = TopK::new(2);
        assert_eq!(t.threshold(), f32::NEG_INFINITY);
        t.push(0, 1.0);
        t.push(1, 2.0);
        assert_eq!(t.threshold(), 1.0);
        t.push(2, 3.0);
        assert_eq!(t.threshold(), 2.0);
    }

    #[test]
    fn drain_sorted_matches_into_sorted_and_empties() {
        let mut rng = Rng::new(11);
        let mut t = TopK::new(7);
        let mut twin = TopK::new(7);
        for i in 0..300u32 {
            let s = rng.f32();
            t.push(i, s);
            twin.push(i, s);
        }
        let mut drained = Vec::new();
        t.drain_sorted(|i, s| drained.push((i, s)));
        assert_eq!(drained, twin.into_sorted());
        assert!(t.is_empty());
        // and the selector is reusable afterwards
        t.push(5, 1.0);
        assert_eq!(t.into_sorted(), vec![(5, 1.0)]);
    }

    #[test]
    fn drain_visits_same_set_as_sorted_and_empties() {
        let mut rng = Rng::new(23);
        let mut t = TopK::new(5);
        let mut twin = TopK::new(5);
        for i in 0..100u32 {
            let s = rng.f32();
            t.push(i, s);
            twin.push(i, s);
        }
        let mut drained = Vec::new();
        t.drain(|i, s| drained.push((i, s)));
        drained.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        assert_eq!(drained, twin.into_sorted());
        assert!(t.is_empty());
    }

    #[test]
    fn reset_changes_k_and_keeps_working() {
        let mut t = TopK::new(2);
        t.push(0, 1.0);
        t.push(1, 2.0);
        t.reset(3);
        for (i, s) in [(0u32, 1.0f32), (1, 5.0), (2, 3.0), (3, 4.0)] {
            t.push(i, s);
        }
        assert_eq!(t.into_sorted(), vec![(1, 5.0), (3, 4.0), (2, 3.0)]);
        let mut t = TopK::new(8);
        t.reset(0);
        t.push(0, 1.0);
        assert!(t.is_empty());
    }

    #[test]
    fn clear_allows_reuse() {
        let mut t = TopK::new(2);
        t.push(0, 1.0);
        t.clear();
        assert!(t.is_empty());
        t.push(1, 9.0);
        assert_eq!(t.into_sorted(), vec![(1, 9.0)]);
    }

    #[test]
    fn matches_naive_sort() {
        prop::check("topk == sort-take-k", 200, |rng| {
            let n = 1 + rng.below(200);
            let k = 1 + rng.below(20);
            let scores: Vec<f32> = (0..n).map(|_| (rng.below(1000) as f32) / 10.0).collect();
            let mut t = TopK::new(k);
            for (i, &s) in scores.iter().enumerate() {
                t.push(i as u32, s);
            }
            let got = t.into_sorted();

            let mut naive: Vec<(u32, f32)> =
                scores.iter().enumerate().map(|(i, &s)| (i as u32, s)).collect();
            naive.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
            naive.truncate(k);
            prop::assert_prop(got == naive, "mismatch with naive selection")
        });
    }

    #[test]
    fn deterministic_given_inputs() {
        let run = || {
            let mut rng = Rng::new(3);
            let mut t = TopK::new(8);
            for i in 0..500 {
                t.push(i, rng.f32());
            }
            t.into_sorted()
        };
        assert_eq!(run(), run());
    }
}
