//! IVF (inverted-file) approximate index: k-means coarse quantizer over the
//! stored vectors; queries probe the `nprobe` nearest cells.
//!
//! This is the scaling path for stores beyond what the exact scan handles
//! within the latency budget. Recall is tunable via `nprobe`; with
//! `nprobe == n_cells` the search is exhaustive and exactly matches
//! [`super::flat::FlatStore`] (tested below).
//!
//! Online inserts assign to the nearest existing centroid — O(n_cells · D) —
//! so feedback ingestion never triggers a rebuild (the paper's real-time
//! adaptation requirement). Centroids can be refreshed offline with
//! [`IvfIndex::rebuild`].

use std::sync::Arc;

use super::kernel;
use super::topk::TopK;
use super::view::FrozenView;
use super::{BatchTopK, Feedback, Hit, ReadIndex, VectorIndex};
use crate::util::Rng;

/// IVF build/search parameters.
#[derive(Debug, Clone, Copy)]
pub struct IvfParams {
    pub n_cells: usize,
    pub nprobe: usize,
    pub kmeans_iters: usize,
    pub seed: u64,
}

impl Default for IvfParams {
    fn default() -> Self {
        IvfParams { n_cells: 64, nprobe: 8, kmeans_iters: 10, seed: 0x1f5 }
    }
}

/// Inverted-file index.
///
/// Member rows are stored twice: id-major in `data` (exact `vector(id)`
/// addressing, rebuild input) and cell-major in `cell_data` (each cell's
/// members contiguous, parallel to `cells`). Probes stream the cell-major
/// slabs, so a probed cell reads like a small flat store — and the
/// batched path runs the query-blocked kernel over each slab once for
/// *all* queries probing that cell instead of degrading to per-query
/// single dots.
#[derive(Debug, Clone)]
pub struct IvfIndex {
    dim: usize,
    params: IvfParams,
    centroids: Vec<f32>,       // [n_cells, dim]
    cells: Vec<Vec<u32>>,      // entry ids per cell
    cell_data: Vec<Vec<f32>>,  // member rows per cell, parallel to `cells`
    data: Vec<f32>,            // all vectors, row-major by id
    payloads: Vec<Feedback>,
}

impl IvfIndex {
    /// Build from existing vectors (k-means over a sample).
    pub fn build(dim: usize, vectors: &[Vec<f32>], payloads: Vec<Feedback>, params: IvfParams) -> Self {
        assert_eq!(vectors.len(), payloads.len());
        let mut idx = IvfIndex {
            dim,
            params,
            centroids: Vec::new(),
            cells: Vec::new(),
            cell_data: Vec::new(),
            data: Vec::new(),
            payloads: Vec::new(),
        };
        for v in vectors {
            assert_eq!(v.len(), dim);
            idx.data.extend_from_slice(v);
        }
        idx.payloads = payloads;
        idx.rebuild();
        idx
    }

    /// Empty index; first `rebuild` happens lazily once vectors exist.
    pub fn new(dim: usize, params: IvfParams) -> Self {
        IvfIndex {
            dim,
            params,
            centroids: Vec::new(),
            cells: Vec::new(),
            cell_data: Vec::new(),
            data: Vec::new(),
            payloads: Vec::new(),
        }
    }

    pub fn params(&self) -> IvfParams {
        self.params
    }

    /// Change the probe width — the recall/latency knob — without
    /// rebuilding (the `perf_hotpath` nprobe sweep rides this). Clamped
    /// to the cell count at search time; 0 behaves as 1.
    pub fn set_nprobe(&mut self, nprobe: usize) {
        self.params.nprobe = nprobe;
    }

    pub fn n_cells(&self) -> usize {
        self.cells.len()
    }

    fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    fn centroid(&self, c: usize) -> &[f32] {
        &self.centroids[c * self.dim..(c + 1) * self.dim]
    }

    /// Nearest centroid by dot product (vectors are normalized).
    fn assign(&self, v: &[f32]) -> usize {
        let dot = kernel::dot_fn();
        let mut best = 0usize;
        let mut best_s = f32::NEG_INFINITY;
        for c in 0..self.n_cells() {
            let s = dot(self.centroid(c), v);
            if s > best_s {
                best_s = s;
                best = c;
            }
        }
        best
    }

    /// Re-run k-means over the current contents and re-assign every vector.
    pub fn rebuild(&mut self) {
        let n = self.payloads.len();
        if n == 0 {
            self.centroids.clear();
            self.cells.clear();
            self.cell_data.clear();
            return;
        }
        let k = self.params.n_cells.min(n).max(1);
        let mut rng = Rng::new(self.params.seed);

        // init: k distinct random rows
        let init = rng.sample_indices(n, k);
        let mut centroids = Vec::with_capacity(k * self.dim);
        for &i in &init {
            centroids.extend_from_slice(self.row(i));
        }
        self.centroids = centroids;
        self.cells = vec![Vec::new(); k];

        let mut assignment = vec![0usize; n];
        for _ in 0..self.params.kmeans_iters {
            // assignment step
            for i in 0..n {
                assignment[i] = self.assign(self.row(i));
            }
            // update step (mean then renormalize — spherical k-means)
            let mut sums = vec![0.0f32; k * self.dim];
            let mut counts = vec![0usize; k];
            for i in 0..n {
                let c = assignment[i];
                counts[c] += 1;
                let row = &self.data[i * self.dim..(i + 1) * self.dim];
                for (d, &x) in row.iter().enumerate() {
                    sums[c * self.dim + d] += x;
                }
            }
            for c in 0..k {
                if counts[c] == 0 {
                    // re-seed empty cell with a random row
                    let r = rng.below(n);
                    sums[c * self.dim..(c + 1) * self.dim]
                        .copy_from_slice(self.row(r));
                    counts[c] = 1;
                }
                let slice = &mut sums[c * self.dim..(c + 1) * self.dim];
                crate::util::l2_normalize(slice);
            }
            self.centroids = sums;
        }

        // final assignment into cells (ids + the cell-major row slabs the
        // probe paths stream)
        for cell in &mut self.cells {
            cell.clear();
        }
        self.cell_data = vec![Vec::new(); k];
        for i in 0..n {
            let c = self.assign(self.row(i));
            self.cells[c].push(i as u32);
            self.cell_data[c].extend_from_slice(&self.data[i * self.dim..(i + 1) * self.dim]);
        }
    }

    /// Fraction of vectors in the largest cell (balance diagnostic).
    pub fn max_cell_load(&self) -> f64 {
        let n = self.payloads.len().max(1);
        self.cells.iter().map(|c| c.len()).max().unwrap_or(0) as f64 / n as f64
    }
}

/// Read-only snapshot view for large stores: an immutable IVF *core*
/// (probed approximately) plus an exact-scanned segmented *tail* of
/// entries inserted after the core was built. Global ids continue the
/// core's id space, so a view over (core of the first n, tail of the
/// rest) addresses the same entries as a flat store of all of them.
///
/// The writer refreshes the core off the read path (an [`IvfIndex`]
/// rebuild over the full contents) and starts a fresh tail; readers keep
/// whatever `Arc`s their snapshot pinned.
#[derive(Debug, Clone)]
pub struct IvfView {
    core: Arc<IvfIndex>,
    tail: FrozenView,
}

impl IvfView {
    pub fn new(core: Arc<IvfIndex>, tail: FrozenView) -> Self {
        assert_eq!(core.dim, tail.dim(), "core/tail dim mismatch");
        IvfView { core, tail }
    }

    pub fn core_len(&self) -> usize {
        self.core.payloads.len()
    }

    pub fn tail_len(&self) -> usize {
        self.tail.len()
    }
}

impl ReadIndex for IvfView {
    fn dim(&self) -> usize {
        self.core.dim
    }

    fn len(&self) -> usize {
        self.core_len() + self.tail.len()
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        let base = self.core_len() as u32;
        let mut topk = TopK::new(k);
        for hit in self.core.search(query, k) {
            topk.push(hit.id, hit.score);
        }
        for hit in self.tail.search(query, k) {
            topk.push(base + hit.id, hit.score);
        }
        topk.into_sorted()
            .into_iter()
            .map(|(id, score)| Hit { id, score })
            .collect()
    }

    fn search_batch_into(&self, queries: &[&[f32]], k: usize, acc: &mut BatchTopK) {
        // probed core candidates land first (begins `acc`), then the
        // exact tail streams through the blocked kernel with ids offset
        // past the core. Top-k of a union is insensitive to push order,
        // so hits are bit-identical to the single-query merge.
        self.core.search_batch_into(queries, k, acc);
        let base = self.core_len() as u32;
        let (topks, tile) = acc.parts_mut();
        self.tail.scan_segments_into(queries, base, topks, tile);
    }

    fn feedback(&self, id: u32) -> &Feedback {
        let base = self.core_len() as u32;
        if id < base {
            self.core.feedback(id)
        } else {
            self.tail.feedback(id - base)
        }
    }

    fn vector(&self, id: u32) -> &[f32] {
        let base = self.core_len() as u32;
        if id < base {
            self.core.vector(id)
        } else {
            self.tail.vector(id - base)
        }
    }
}

impl ReadIndex for IvfIndex {
    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.payloads.len()
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        assert_eq!(query.len(), self.dim, "query dim mismatch");
        if self.payloads.is_empty() || k == 0 {
            return Vec::new();
        }
        // rank cells by centroid similarity
        let dot = kernel::dot_fn();
        let mut cell_scores = TopK::new(self.params.nprobe.max(1).min(self.n_cells()));
        for c in 0..self.n_cells() {
            cell_scores.push(c as u32, dot(self.centroid(c), query));
        }
        let mut topk = TopK::new(k);
        for (cell, _) in cell_scores.into_sorted() {
            // stream the cell's contiguous slab (same scores as id-major
            // access — identical rows, identical kernel)
            let ids = &self.cells[cell as usize];
            let rows = &self.cell_data[cell as usize];
            for (pos, &id) in ids.iter().enumerate() {
                let s = dot(&rows[pos * self.dim..(pos + 1) * self.dim], query);
                topk.push(id, s);
            }
        }
        topk.into_sorted()
            .into_iter()
            .map(|(id, score)| Hit { id, score })
            .collect()
    }

    fn search_batch_into(&self, queries: &[&[f32]], k: usize, acc: &mut BatchTopK) {
        for q in queries {
            assert_eq!(q.len(), self.dim, "query dim mismatch");
        }
        acc.begin(queries.len(), k);
        if self.payloads.is_empty() || k == 0 {
            return;
        }
        // rank every query's cells in one blocked pass over the (small,
        // contiguous) centroid matrix — the GEMM-shaped part of probing
        let n_cells = self.n_cells();
        let nprobe = self.params.nprobe.max(1).min(n_cells);
        let backend = kernel::active();
        let (topks, tile) = acc.parts_mut();
        tile.clear();
        tile.resize(queries.len() * n_cells, 0.0);
        backend.scan_block_into(queries, self.dim, &self.centroids, tile.as_mut_slice());

        // invert the per-query probe selections into per-cell query lists:
        // each probed cell's contiguous slab then streams through the
        // query-blocked kernel ONCE for every query probing it, instead of
        // degrading to per-query single-dot probes. Per-query probed-cell
        // sets are unchanged and top-k retention is push-order independent,
        // so hits stay bit-identical to the single-query path.
        let mut cell_sel = TopK::new(nprobe);
        let mut probes: Vec<(u32, u32)> = Vec::with_capacity(queries.len() * nprobe);
        for qi in 0..queries.len() {
            cell_sel.reset(nprobe);
            for (c, &s) in tile[qi * n_cells..(qi + 1) * n_cells].iter().enumerate() {
                cell_sel.push(c as u32, s);
            }
            cell_sel.drain(|cell, _| probes.push((cell, qi as u32)));
        }
        probes.sort_unstable();

        let mut qsub: Vec<&[f32]> = Vec::new();
        let mut qidx: Vec<usize> = Vec::new();
        let mut i = 0;
        while i < probes.len() {
            let cell = probes[i].0 as usize;
            qsub.clear();
            qidx.clear();
            while i < probes.len() && probes[i].0 as usize == cell {
                let qi = probes[i].1 as usize;
                qsub.push(queries[qi]);
                qidx.push(qi);
                i += 1;
            }
            let ids = &self.cells[cell];
            let rows = &self.cell_data[cell];
            let mut start = 0usize;
            while start < ids.len() {
                let block = (ids.len() - start).min(kernel::SCAN_BLOCK_ROWS);
                tile.clear();
                tile.resize(qsub.len() * block, 0.0);
                backend.scan_block_into(
                    &qsub,
                    self.dim,
                    &rows[start * self.dim..(start + block) * self.dim],
                    tile.as_mut_slice(),
                );
                for (j, &qi) in qidx.iter().enumerate() {
                    for (r, &s) in tile[j * block..(j + 1) * block].iter().enumerate() {
                        topks[qi].push(ids[start + r], s);
                    }
                }
                start += block;
            }
        }
    }

    fn feedback(&self, id: u32) -> &Feedback {
        &self.payloads[id as usize]
    }

    fn vector(&self, id: u32) -> &[f32] {
        self.row(id as usize)
    }
}

impl VectorIndex for IvfIndex {
    fn add(&mut self, vector: &[f32], feedback: Feedback) -> u32 {
        assert_eq!(vector.len(), self.dim, "vector dim mismatch");
        let id = self.payloads.len() as u32;
        self.data.extend_from_slice(vector);
        self.payloads.push(feedback);
        if self.cells.is_empty() {
            // bootstrap: first vector becomes the first centroid
            self.centroids.extend_from_slice(vector);
            self.cells.push(vec![id]);
            self.cell_data.push(vector.to_vec());
        } else {
            let c = self.assign(vector);
            self.cells[c].push(id);
            self.cell_data[c].extend_from_slice(vector);
        }
        id
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;
    use crate::util::{prop, Rng};

    fn build_random(
        rng: &mut Rng,
        n: usize,
        dim: usize,
        params: IvfParams,
    ) -> (IvfIndex, Vec<Vec<f32>>) {
        let vectors: Vec<Vec<f32>> = (0..n).map(|_| random_unit(rng, dim)).collect();
        let payloads = (0..n).map(dummy_feedback).collect();
        (IvfIndex::build(dim, &vectors, payloads, params), vectors)
    }

    #[test]
    fn full_probe_matches_flat_exactly() {
        prop::check("ivf nprobe=all == exact", 20, |rng| {
            let n = 50 + rng.below(200);
            let params = IvfParams { n_cells: 8, nprobe: 8, kmeans_iters: 4, seed: 1 };
            let (idx, vectors) = build_random(rng, n, 16, params);
            let q = random_unit(rng, 16);
            let hits = idx.search(&q, 10);
            let naive = naive_search(&vectors, &q, 10);
            for (h, (ni, ns)) in hits.iter().zip(&naive) {
                prop::assert_close(h.score as f64, *ns as f64, 1e-5, "score")?;
                if (h.score - ns).abs() > 1e-6 {
                    prop::assert_prop(h.id == *ni, "id")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn partial_probe_recall_reasonable() {
        // clustered data: recall@10 with nprobe=4/32 should be high
        let mut rng = Rng::new(11);
        let dim = 32;
        let n_clusters = 16;
        let centers: Vec<Vec<f32>> =
            (0..n_clusters).map(|_| random_unit(&mut rng, dim)).collect();
        let mut vectors = Vec::new();
        for i in 0..800 {
            let c = &centers[i % n_clusters];
            let mut v: Vec<f32> = c
                .iter()
                .map(|&x| x + 0.15 * rng.normal() as f32)
                .collect();
            crate::util::l2_normalize(&mut v);
            vectors.push(v);
        }
        let payloads = (0..vectors.len()).map(dummy_feedback).collect();
        let params = IvfParams { n_cells: 32, nprobe: 4, kmeans_iters: 10, seed: 3 };
        let idx = IvfIndex::build(dim, &vectors, payloads, params);

        let mut recall_sum = 0.0;
        let trials = 40;
        for t in 0..trials {
            let q = &vectors[t * 7 % vectors.len()];
            let approx: Vec<u32> = idx.search(q, 10).iter().map(|h| h.id).collect();
            let exact: Vec<u32> =
                naive_search(&vectors, q, 10).iter().map(|(i, _)| *i).collect();
            let inter = approx.iter().filter(|i| exact.contains(i)).count();
            recall_sum += inter as f64 / 10.0;
        }
        let recall = recall_sum / trials as f64;
        assert!(recall > 0.8, "recall@10 = {recall}");
    }

    #[test]
    fn online_insert_searchable() {
        let mut rng = Rng::new(5);
        let (mut idx, _) = build_random(&mut rng, 100, 16, IvfParams::default());
        let v = random_unit(&mut rng, 16);
        let id = idx.add(&v, dummy_feedback(999));
        // exhaustive probe must find the fresh vector as its own NN
        let mut p = idx.params();
        p.nprobe = idx.n_cells();
        let exhaustive = IvfIndex { params: p, ..idx.clone() };
        let hits = exhaustive.search(&v, 1);
        assert_eq!(hits[0].id, id);
        assert!((hits[0].score - 1.0).abs() < 1e-5);
    }

    #[test]
    fn empty_index_bootstrap() {
        let mut idx = IvfIndex::new(8, IvfParams::default());
        assert!(idx.search(&[1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0], 3).is_empty());
        let mut rng = Rng::new(2);
        let v = random_unit(&mut rng, 8);
        idx.add(&v, dummy_feedback(0));
        assert_eq!(idx.search(&v, 1)[0].id, 0);
    }

    #[test]
    fn rebuild_preserves_contents() {
        let mut rng = Rng::new(9);
        let (mut idx, vectors) = build_random(&mut rng, 150, 16, IvfParams::default());
        idx.rebuild();
        assert_eq!(idx.len(), 150);
        // every id still present in exactly one cell
        let mut seen = vec![false; 150];
        for c in 0..idx.n_cells() {
            for &id in &idx.cells[c] {
                assert!(!seen[id as usize], "duplicate id {id}");
                seen[id as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
        // vectors unchanged
        for (i, v) in vectors.iter().enumerate() {
            assert_eq!(idx.vector(i as u32), v.as_slice());
        }
    }

    #[test]
    fn cells_not_degenerate() {
        let mut rng = Rng::new(13);
        let (idx, _) = build_random(&mut rng, 500, 16, IvfParams::default());
        assert!(idx.max_cell_load() < 0.5, "load = {}", idx.max_cell_load());
    }

    #[test]
    fn exhaustive_probe_equals_flat_store_exactly() {
        // ISSUE property: with nprobe == n_cells the IVF search is
        // exhaustive and must return *exactly* FlatStore's top-k —
        // same ids, same scores, same tie-breaks — on random stores of
        // size 1..=2048 and random dims, both for a batch-built index
        // and after interleaved online inserts.
        use super::super::flat::FlatStore;
        prop::check("ivf(nprobe=all) == flat", 12, |rng| {
            let dim = [4, 8, 16, 32][rng.below(4)];
            let n = 1 + rng.below(2048);
            let n_cells = 1 + rng.below(24);
            let params = IvfParams {
                n_cells,
                nprobe: n_cells,
                kmeans_iters: 3,
                seed: rng.next_u64(),
            };
            // batch-build over the first half, then interleave online
            // inserts with searches for the second half
            let half = n / 2;
            let vectors: Vec<Vec<f32>> =
                (0..n).map(|_| random_unit(rng, dim)).collect();
            let payloads = (0..half).map(dummy_feedback).collect();
            let mut idx = IvfIndex::build(dim, &vectors[..half], payloads, params);
            let mut flat = FlatStore::new(dim);
            for (i, v) in vectors[..half].iter().enumerate() {
                flat.add(v, dummy_feedback(i));
            }
            for (i, v) in vectors[half..].iter().enumerate() {
                // interleave: check agreement periodically mid-insert
                // (every insert would be O(n^2) in debug builds)
                if i % 41 == 0 {
                    // nprobe tracks the (possibly grown) cell count so
                    // the probe stays exhaustive after online inserts
                    idx.params.nprobe = idx.n_cells().max(1);
                    let k = 1 + rng.below(20);
                    let q = random_unit(rng, dim);
                    prop::assert_prop(
                        idx.search(&q, k) == flat.search(&q, k),
                        "exhaustive ivf != flat during interleaved inserts",
                    )?;
                }
                idx.add(v, dummy_feedback(half + i));
                flat.add(v, dummy_feedback(half + i));
            }
            idx.params.nprobe = idx.n_cells().max(1);
            let q = random_unit(rng, dim);
            let k = 1 + rng.below(20);
            prop::assert_prop(
                idx.search(&q, k) == flat.search(&q, k),
                "exhaustive ivf != flat after all inserts",
            )
        });
    }

    #[test]
    fn ivf_view_matches_flat_over_core_plus_tail() {
        use super::super::flat::FlatStore;
        use super::super::view::SegmentStore;
        prop::check("ivf view == flat", 15, |rng| {
            let dim = 16;
            let n_core = 30 + rng.below(200);
            let n_tail = rng.below(100);
            let vectors: Vec<Vec<f32>> =
                (0..n_core + n_tail).map(|_| random_unit(rng, dim)).collect();
            let params = IvfParams { n_cells: 8, nprobe: 8, kmeans_iters: 3, seed: 5 };
            let payloads = (0..n_core).map(dummy_feedback).collect();
            let core = Arc::new(IvfIndex::build(dim, &vectors[..n_core], payloads, params));
            let mut tail_store = SegmentStore::new(dim);
            let mut flat = FlatStore::new(dim);
            for (i, v) in vectors.iter().enumerate() {
                flat.add(v, dummy_feedback(i));
                if i >= n_core {
                    VectorIndex::add(&mut tail_store, v, dummy_feedback(i));
                }
            }
            let view = IvfView::new(core, tail_store.freeze());
            prop::assert_prop(view.len() == n_core + n_tail, "view length")?;
            let q = random_unit(rng, dim);
            let a = view.search(&q, 12);
            let b = flat.search(&q, 12);
            prop::assert_prop(a == b, "view hits != flat hits")?;
            // payload/vector addressing agrees across the core/tail seam
            for _ in 0..10 {
                let id = rng.below(n_core + n_tail) as u32;
                prop::assert_prop(view.vector(id) == flat.vector(id), "vector mismatch")?;
                prop::assert_prop(view.feedback(id) == flat.feedback(id), "payload mismatch")?;
            }
            Ok(())
        });
    }

    #[test]
    fn batch_search_bit_identical_to_singles_at_any_nprobe() {
        // the blocked centroid-ranking + probe path must return exactly
        // the single-query hits — including *partial* probes, where the
        // probed cell set itself must match
        prop::check("ivf batch == singles", 15, |rng| {
            let dim = [8, 16, 32][rng.below(3)];
            let n = 1 + rng.below(600);
            let n_cells = 1 + rng.below(24);
            let nprobe = 1 + rng.below(n_cells);
            let params = IvfParams { n_cells, nprobe, kmeans_iters: 3, seed: rng.next_u64() };
            let (idx, _) = build_random(rng, n, dim, params);
            let k = 1 + rng.below(20);
            let n_q = 1 + rng.below(9);
            let queries: Vec<Vec<f32>> = (0..n_q).map(|_| random_unit(rng, dim)).collect();
            let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
            let batch = idx.search_batch(&qrefs, k);
            for (q, hits) in qrefs.iter().zip(&batch) {
                prop::assert_prop(hits == &idx.search(q, k), "ivf batch hits != single hits")?;
            }
            Ok(())
        });
    }

    #[test]
    fn ivf_view_batch_search_bit_identical_to_singles() {
        use super::super::view::SegmentStore;
        prop::check("ivf view batch == singles", 12, |rng| {
            let dim = 16;
            let n_core = 30 + rng.below(200);
            let n_tail = rng.below(100);
            let n_cells = 1 + rng.below(12);
            let nprobe = 1 + rng.below(n_cells);
            let params = IvfParams { n_cells, nprobe, kmeans_iters: 3, seed: 7 };
            let vectors: Vec<Vec<f32>> =
                (0..n_core + n_tail).map(|_| random_unit(rng, dim)).collect();
            let payloads = (0..n_core).map(dummy_feedback).collect();
            let core = IvfIndex::build(dim, &vectors[..n_core], payloads, params);
            let mut tail = SegmentStore::new(dim);
            for (i, v) in vectors[n_core..].iter().enumerate() {
                VectorIndex::add(&mut tail, v, dummy_feedback(n_core + i));
            }
            let view = IvfView::new(Arc::new(core), tail.freeze());
            let k = 1 + rng.below(15);
            let queries: Vec<Vec<f32>> = (0..6).map(|_| random_unit(rng, dim)).collect();
            let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
            let batch = view.search_batch(&qrefs, k);
            for (q, hits) in qrefs.iter().zip(&batch) {
                prop::assert_prop(hits == &view.search(q, k), "view batch hits != singles")?;
            }
            Ok(())
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Rng::new(21);
        let mut r2 = Rng::new(21);
        let (a, _) = build_random(&mut r1, 120, 8, IvfParams::default());
        let (b, _) = build_random(&mut r2, 120, 8, IvfParams::default());
        let q = random_unit(&mut Rng::new(22), 8);
        assert_eq!(a.search(&q, 5), b.search(&q, 5));
    }
}
