//! SQ8 scalar-quantized corpus scoring with an exact rerank tail (§Perf).
//!
//! The flat scan is memory-bandwidth-bound once the SIMD kernels exist:
//! at dim 256 every query streams 1 KiB per stored vector. This module
//! cuts that 4x by scanning 1-byte codes instead of f32s, then claws the
//! lost precision back with an exact rerank over a small over-fetched
//! candidate set.
//!
//! ## Layout
//!
//! Quantization is a per-segment *sidecar*, not a replacement: each
//! sealed [`Segment`] at least [`QUANT_MIN_SEGMENT_ROWS`] rows long gets
//! a [`QuantSegment`] — an affine codebook (`mid`/`scale` from the
//! segment's min/max) plus one i8 code per element — while the exact f32
//! rows stay resident for reranking, `vector()` access, and ELO replay.
//! Segments below the floor (the write-fresh tail under binary-counter
//! merging) scan exactly; that is the "exact tail" of the publication
//! policy. Because segments are immutable, sidecars are encoded once per
//! merge in [`QuantCache`] (off the route path, at publish), costing the
//! same amortized O(log n) per entry as segment merging itself.
//!
//! ## Scoring
//!
//! With a row decoded as `x ≈ mid + scale·c` and the query quantized
//! symmetrically as `q ≈ qscale·u` (both `c`, `u` ∈ [-127, 127]):
//!
//! ```text
//! q·x ≈ (qscale·mid)·Σu  +  (qscale·scale)·Σ u·c
//! ```
//!
//! Both sums are exact i32s from the widening int8 kernels
//! ([`kernel::Backend::dot_i8`]), so the approximate score is two f32
//! multiplies and one add on identical integers — **bit-identical on
//! every backend**, single-query or blocked, by arithmetic alone.
//!
//! ## Exact rerank
//!
//! A scan over-fetches `rerank_factor · k` candidates on approximate
//! scores, then rescores each through the exact f32 kernel before the
//! final top-k. Quantization error can therefore only *drop* a true
//! neighbor from the candidate set, never corrupt a returned score; with
//! a rerank set covering the whole quantized corpus the result is
//! bit-identical to the flat path (property-tested below), and at the
//! default `rerank_factor` the bench gate holds `recall_ratio ≥ 0.99`.

use std::sync::Arc;

use super::kernel;
use super::topk::TopK;
use super::view::{FrozenView, Segment};
use super::{BatchTopK, Feedback, Hit, ReadIndex};

/// Sealed segments shorter than this stay exact (the publication
/// policy's exact tail): encoding tiny write-fresh segments would buy no
/// bandwidth and churn the cache on every merge.
pub const QUANT_MIN_SEGMENT_ROWS: usize = 256;

/// Default candidate over-fetch multiple for the exact rerank
/// (`[quant] rerank_factor`).
pub const DEFAULT_RERANK_FACTOR: usize = 4;

/// An immutable SQ8 sidecar for one sealed segment: per-segment affine
/// codebook plus one i8 code per element, row-major like the segment.
#[derive(Debug)]
pub struct QuantSegment {
    dim: usize,
    len: usize,
    /// Codebook midpoint: `(min + max) / 2` over the segment's elements.
    mid: f32,
    /// Codebook step per code unit: `(max - min) / 2 / 127`; decode is
    /// `mid + scale·code`, so the round-trip error is at most `scale/2`.
    scale: f32,
    codes: Vec<i8>,
}

impl QuantSegment {
    /// Encode a row-major f32 slab with a min/max affine codebook.
    pub fn encode(dim: usize, data: &[f32]) -> Self {
        assert!(dim > 0, "dim must be positive");
        assert_eq!(data.len() % dim, 0, "slab not a multiple of dim");
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &x in data {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        if data.is_empty() {
            lo = 0.0;
            hi = 0.0;
        }
        let mid = (lo + hi) * 0.5;
        let half = (hi - lo) * 0.5;
        let scale = if half > 0.0 { half / 127.0 } else { 0.0 };
        let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
        let codes = data
            .iter()
            .map(|&x| ((x - mid) * inv).round().clamp(-127.0, 127.0) as i8)
            .collect();
        QuantSegment { dim, len: data.len() / dim, mid, scale, codes }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The codebook step: decode error is bounded by `step() / 2` (plus
    /// one f32 rounding) — the property the round-trip test asserts.
    pub fn step(&self) -> f32 {
        self.scale
    }

    /// Bytes streamed when scanning this sidecar (1 per element).
    pub fn scan_bytes(&self) -> usize {
        self.codes.len()
    }

    /// Decode one row back to f32 (tests / diagnostics).
    pub fn decode_row(&self, row: usize) -> Vec<f32> {
        self.codes[row * self.dim..(row + 1) * self.dim]
            .iter()
            .map(|&c| self.mid + self.scale * c as f32)
            .collect()
    }

    /// Approximate score from the exact integer accumulator. Two
    /// multiplies and one add on identical integers — the same bits from
    /// every backend and every scan shape.
    #[inline]
    fn score(&self, q: &QuantQuery, acc: i32) -> f32 {
        (q.scale * self.mid) * (q.sum as f32) + (q.scale * self.scale) * (acc as f32)
    }

    /// Single-query approximate scan: push `(base + row, score)` for
    /// every row into the candidate selector.
    pub(crate) fn scan_into(&self, q: &QuantQuery, base: u32, cand: &mut TopK) {
        let backend = kernel::active();
        for r in 0..self.len {
            let acc = backend.dot_i8(&q.codes, &self.codes[r * self.dim..(r + 1) * self.dim]);
            cand.push(base + r as u32, self.score(q, acc));
        }
    }

    /// Blocked multi-query approximate scan ([`kernel::SCAN_BLOCK_ROWS`]
    /// rows per tile, same shape as the f32 scan): identical scores to
    /// per-query [`QuantSegment::scan_into`] because the accumulators are
    /// exact. `qcodes` are `queries`' code slices (hoisted by the caller).
    pub(crate) fn scan_block_into(
        &self,
        queries: &[QuantQuery],
        qcodes: &[&[i8]],
        base: u32,
        cands: &mut [TopK],
        itile: &mut Vec<i32>,
    ) {
        debug_assert_eq!(queries.len(), cands.len(), "query/selector count mismatch");
        let backend = kernel::active();
        let mut start = 0usize;
        while start < self.len {
            let block = (self.len - start).min(kernel::SCAN_BLOCK_ROWS);
            itile.clear();
            itile.resize(queries.len() * block, 0);
            backend.scan_i8_block_into(
                qcodes,
                self.dim,
                &self.codes[start * self.dim..(start + block) * self.dim],
                itile.as_mut_slice(),
            );
            for (qi, cand) in cands.iter_mut().enumerate() {
                let q = &queries[qi];
                for (r, &acc) in itile[qi * block..(qi + 1) * block].iter().enumerate() {
                    cand.push(base + (start + r) as u32, self.score(q, acc));
                }
            }
            start += block;
        }
    }
}

/// A query quantized symmetrically (`q ≈ scale·codes`, no offset) for
/// the int8 scan, with the code sum pre-folded for the affine correction.
#[derive(Debug)]
pub struct QuantQuery {
    scale: f32,
    sum: i32,
    codes: Vec<i8>,
}

impl QuantQuery {
    pub fn encode(q: &[f32]) -> Self {
        let amax = q.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let scale = if amax > 0.0 { amax / 127.0 } else { 0.0 };
        let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
        let mut sum = 0i32;
        let codes = q
            .iter()
            .map(|&x| {
                let c = (x * inv).round().clamp(-127.0, 127.0) as i32;
                sum += c;
                c as i8
            })
            .collect();
        QuantQuery { scale, sum, codes }
    }
}

/// Writer-side sidecar cache: segments are immutable, so each one is
/// encoded exactly once per merge. Holding strong `Arc`s to both halves
/// keeps pointer identity stable; [`QuantCache::refresh`] drops entries
/// for merged-away segments so the cache tracks the live set.
#[derive(Debug, Default)]
pub struct QuantCache {
    entries: Vec<(Arc<Segment>, Arc<QuantSegment>)>,
}

impl QuantCache {
    pub fn new() -> Self {
        QuantCache::default()
    }

    /// Number of cached sidecars (diagnostics).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Read-only SQ8 view: the exact [`FrozenView`] plus quantized sidecars
/// for its large sealed segments. Scans stream the codes, over-fetch
/// `rerank_factor · k` candidates, rerank them exactly, and merge with
/// the exact scan of unquantized (tail) segments.
#[derive(Debug, Clone)]
pub struct QuantView {
    exact: FrozenView,
    /// Parallel to `exact.segments()`: `None` = segment scans exactly.
    quant: Vec<Option<Arc<QuantSegment>>>,
    rerank_factor: usize,
}

impl QuantView {
    /// Build a quantized view over a frozen snapshot, encoding sidecars
    /// for segments of at least `min_rows` rows (cached across publishes
    /// in `cache`). Runs on the writer at publish time — off the route
    /// path. `min_rows = 0` quantizes every non-empty segment.
    pub fn build(
        exact: FrozenView,
        cache: &mut QuantCache,
        min_rows: usize,
        rerank_factor: usize,
    ) -> Self {
        let mut fresh = Vec::new();
        let mut quant = Vec::with_capacity(exact.segments().len());
        for seg in exact.segments() {
            if seg.len() < min_rows.max(1) {
                quant.push(None);
                continue;
            }
            let sidecar = cache
                .entries
                .iter()
                .find(|(s, _)| Arc::ptr_eq(s, seg))
                .map(|(_, q)| q.clone())
                .unwrap_or_else(|| {
                    Arc::new(QuantSegment::encode(exact.dim(), seg.vectors()))
                });
            fresh.push((seg.clone(), sidecar.clone()));
            quant.push(Some(sidecar));
        }
        cache.entries = fresh;
        QuantView { exact, quant, rerank_factor: rerank_factor.max(1) }
    }

    pub fn rerank_factor(&self) -> usize {
        self.rerank_factor
    }

    /// Rows covered by quantized sidecars (the rest scan exactly).
    pub fn quantized_rows(&self) -> usize {
        self.quant.iter().flatten().map(|q| q.len()).sum()
    }

    /// Bytes streamed per single query at `k`: 1 per quantized element,
    /// 4 per exact-tail element, plus the exact rows the rerank touches.
    pub fn scan_bytes_per_query(&self, k: usize) -> usize {
        let dim = self.exact.dim();
        let mut bytes = 0usize;
        for (seg, q) in self.exact.segments().iter().zip(&self.quant) {
            bytes += match q {
                Some(qs) => qs.scan_bytes(),
                None => seg.len() * dim * 4,
            };
        }
        let rerank = self.rerank_factor.saturating_mul(k).min(self.quantized_rows());
        bytes + rerank * dim * 4
    }

    /// Rescore every over-fetched candidate through the exact kernel into
    /// the final selector. Push order is immaterial: TopK retention is a
    /// function of the (score, id) set, and the scores here are the same
    /// exact-kernel bits the flat path pushes.
    fn rerank_into(&self, query: &[f32], cand: &mut TopK, out: &mut TopK) {
        let dot = kernel::dot_fn();
        cand.drain(|id, _| out.push(id, dot(self.exact.vector(id), query)));
    }
}

impl ReadIndex for QuantView {
    fn dim(&self) -> usize {
        self.exact.dim()
    }

    fn len(&self) -> usize {
        self.exact.len()
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        assert_eq!(query.len(), self.exact.dim(), "query dim mismatch");
        if k == 0 || self.exact.is_empty() {
            return Vec::new();
        }
        if self.quantized_rows() == 0 {
            return self.exact.search(query, k);
        }
        let q = QuantQuery::encode(query);
        let mut cand = TopK::new(self.rerank_factor.saturating_mul(k).max(k));
        let mut out = TopK::new(k);
        for (i, seg) in self.exact.segments().iter().enumerate() {
            let base = self.exact.bases()[i];
            match &self.quant[i] {
                Some(qs) => qs.scan_into(&q, base, &mut cand),
                None => seg.scan_into(query, base, &mut out),
            }
        }
        self.rerank_into(query, &mut cand, &mut out);
        out.into_sorted()
            .into_iter()
            .map(|(id, score)| Hit { id, score })
            .collect()
    }

    fn search_batch_into(&self, queries: &[&[f32]], k: usize, acc: &mut BatchTopK) {
        for q in queries {
            assert_eq!(q.len(), self.exact.dim(), "query dim mismatch");
        }
        acc.begin(queries.len(), k);
        if k == 0 || queries.is_empty() || self.exact.is_empty() {
            return;
        }
        let (topks, tile) = acc.parts_mut();
        if self.quantized_rows() == 0 {
            self.exact.scan_segments_into(queries, 0, topks, tile);
            return;
        }
        let qq: Vec<QuantQuery> = queries.iter().map(|q| QuantQuery::encode(q)).collect();
        let qcodes: Vec<&[i8]> = qq.iter().map(|q| q.codes.as_slice()).collect();
        let cap = self.rerank_factor.saturating_mul(k).max(k);
        let mut cands: Vec<TopK> = (0..queries.len()).map(|_| TopK::new(cap)).collect();
        let mut itile: Vec<i32> = Vec::new();
        for (i, seg) in self.exact.segments().iter().enumerate() {
            let base = self.exact.bases()[i];
            match &self.quant[i] {
                Some(qs) => qs.scan_block_into(&qq, &qcodes, base, &mut cands, &mut itile),
                None => seg.scan_block_into(queries, base, topks, tile),
            }
        }
        for (qi, cand) in cands.iter_mut().enumerate() {
            self.rerank_into(queries[qi], cand, &mut topks[qi]);
        }
    }

    fn feedback(&self, id: u32) -> &Feedback {
        self.exact.feedback(id)
    }

    fn vector(&self, id: u32) -> &[f32] {
        self.exact.vector(id)
    }
}

#[cfg(test)]
mod tests {
    use super::super::flat::FlatStore;
    use super::super::testutil::*;
    use super::super::view::SegmentStore;
    use super::super::VectorIndex;
    use super::*;
    use crate::util::{prop, Rng};

    fn quantized_twin(
        rng: &mut Rng,
        n: usize,
        dim: usize,
        min_rows: usize,
        rerank_factor: usize,
    ) -> (FlatStore, QuantView, QuantCache) {
        let mut flat = FlatStore::new(dim);
        let mut seg = SegmentStore::new(dim);
        for i in 0..n {
            let v = random_unit(rng, dim);
            flat.add(&v, dummy_feedback(i));
            seg.add(&v, dummy_feedback(i));
        }
        let mut cache = QuantCache::new();
        let view = QuantView::build(seg.freeze(), &mut cache, min_rows, rerank_factor);
        (flat, view, cache)
    }

    #[test]
    fn roundtrip_error_bounded_by_codebook_step() {
        // ISSUE property: |decode(encode(x)) - x| <= step/2 for every
        // element, across magnitudes and degenerate (constant) segments
        prop::check("sq8 roundtrip <= step/2", 60, |rng| {
            let dim = 1 + rng.below(64);
            let rows = 1 + rng.below(40);
            let scale = [1.0f32, 1e-3, 1e3][rng.below(3)];
            let data: Vec<f32> = if rng.below(8) == 0 {
                vec![scale; rows * dim] // constant slab: step = 0, exact
            } else {
                prop::vec_f32(rng, rows * dim).iter().map(|x| x * scale).collect()
            };
            let qs = QuantSegment::encode(dim, &data);
            // half a step, widened a hair for the two f32 roundings in
            // the encode/decode path
            let bound = qs.step() * 0.5 * 1.001 + f32::EPSILON;
            for r in 0..rows {
                let decoded = qs.decode_row(r);
                for (d, &x) in data[r * dim..(r + 1) * dim].iter().enumerate() {
                    let err = (decoded[d] - x).abs();
                    prop::assert_prop(
                        err <= bound,
                        &format!("row {r} dim {d}: err {err} > bound {bound}"),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn full_rerank_bit_identical_to_flat() {
        // ISSUE property: with the rerank set covering the whole corpus,
        // every returned score comes from the exact kernel, so the view
        // must equal FlatStore exactly — ids, scores, tie-breaks. (The
        // EAGLE_KERNEL=portable CI arm re-runs this on the portable int8
        // dispatch; SIMD hosts cover their backend here.)
        prop::check("sq8 full rerank == flat", 25, |rng| {
            let dim = [8, 16, 64][rng.below(3)];
            let n = 1 + rng.below(500);
            let k = 1 + rng.below(20);
            // rerank_factor * k >= n: candidates = the whole corpus
            let rerank_factor = n / k.max(1) + 1;
            let (flat, view, _) = quantized_twin(rng, n, dim, 1, rerank_factor);
            prop::assert_prop(view.quantized_rows() == n, "all rows quantized")?;
            let q = random_unit(rng, dim);
            prop::assert_prop(view.search(&q, k) == flat.search(&q, k), "hits != flat")
        });
    }

    #[test]
    fn batch_bit_identical_to_singles() {
        // blocked int8 scan + rerank must retain exactly the single-query
        // hits: integer accumulators make the approximate scores identical
        // across scan shapes, and rerank scores are exact-kernel bits
        prop::check("sq8 batch == singles", 20, |rng| {
            let dim = [8, 32][rng.below(2)];
            let n = 1 + rng.below(400);
            let k = 1 + rng.below(15);
            let factor = 1 + rng.below(6);
            let min_rows = [1, 64][rng.below(2)];
            let (_, view, _) = quantized_twin(rng, n, dim, min_rows, factor);
            let n_q = 1 + rng.below(9);
            let queries: Vec<Vec<f32>> = (0..n_q).map(|_| random_unit(rng, dim)).collect();
            let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
            let batch = view.search_batch(&qrefs, k);
            for (q, hits) in qrefs.iter().zip(&batch) {
                prop::assert_prop(hits == &view.search(q, k), "batch hits != single hits")?;
            }
            Ok(())
        });
    }

    #[test]
    fn recall_high_at_default_rerank_factor() {
        // the quality gate the bench sweep enforces in CI, in miniature:
        // top-k overlap with the exact path at the default over-fetch
        let mut rng = Rng::new(0x5108);
        let dim = 64;
        let n = 4096;
        let k = 20;
        let (flat, view, _) = quantized_twin(&mut rng, n, dim, 1, DEFAULT_RERANK_FACTOR);
        let mut hit = 0usize;
        let trials = 40;
        for _ in 0..trials {
            let q = random_unit(&mut rng, dim);
            let exact: Vec<u32> = flat.search(&q, k).iter().map(|h| h.id).collect();
            let approx = view.search(&q, k);
            hit += approx.iter().filter(|h| exact.contains(&h.id)).count();
        }
        let recall = hit as f64 / (trials * k) as f64;
        assert!(recall >= 0.99, "recall@{k} = {recall}");
    }

    #[test]
    fn unquantized_view_is_exact_passthrough() {
        // min_rows above every segment size: no sidecars, pure exact scan
        let mut rng = Rng::new(7);
        let (flat, view, cache) = quantized_twin(&mut rng, 200, 16, usize::MAX, 4);
        assert_eq!(view.quantized_rows(), 0);
        assert!(cache.is_empty());
        let q = random_unit(&mut rng, 16);
        assert_eq!(view.search(&q, 10), flat.search(&q, 10));
        let qrefs = [q.as_slice()];
        assert_eq!(view.search_batch(&qrefs, 10)[0], flat.search(&q, 10));
    }

    #[test]
    fn cache_reuses_sidecars_and_drops_merged_segments() {
        let mut rng = Rng::new(9);
        let dim = 8;
        let mut seg = SegmentStore::new(dim);
        for i in 0..300 {
            seg.add(&random_unit(&mut rng, dim), dummy_feedback(i));
        }
        let mut cache = QuantCache::new();
        let v1 = QuantView::build(seg.freeze(), &mut cache, 1, 4);
        let n_cached = cache.len();
        assert!(n_cached > 0);
        // re-publish without inserts: same segments, sidecars shared
        let v2 = QuantView::build(seg.freeze(), &mut cache, 1, 4);
        assert_eq!(cache.len(), n_cached);
        for (a, b) in v1.quant.iter().zip(&v2.quant) {
            match (a, b) {
                (Some(a), Some(b)) => assert!(Arc::ptr_eq(a, b), "sidecar re-encoded"),
                _ => panic!("sidecar disappeared"),
            }
        }
        // grow until merges consume the old segments: stale entries drop
        for i in 300..1200 {
            seg.add(&random_unit(&mut rng, dim), dummy_feedback(i));
            if i % 100 == 0 {
                let _ = QuantView::build(seg.freeze(), &mut cache, 1, 4);
            }
        }
        let view = QuantView::build(seg.freeze(), &mut cache, 1, 4);
        assert!(cache.len() <= view.exact.segment_count());
    }

    #[test]
    fn bytes_per_query_counts_codes_not_floats() {
        let mut rng = Rng::new(11);
        let dim = 32;
        let (_, view, _) = quantized_twin(&mut rng, 1024, dim, 1, 4);
        let k = 10;
        let exact_bytes = 1024 * dim * 4;
        let got = view.scan_bytes_per_query(k);
        // codes (1024*dim) + rerank (40 rows of f32) — far under 4x
        assert_eq!(got, 1024 * dim + 4 * k * dim * 4);
        assert!(got * 3 < exact_bytes, "{got} vs {exact_bytes}");
    }

    #[test]
    fn empty_and_k_zero() {
        let mut cache = QuantCache::new();
        let view = QuantView::build(FrozenView::empty(4), &mut cache, 1, 4);
        assert!(view.search(&[1.0, 0.0, 0.0, 0.0], 5).is_empty());
        let mut rng = Rng::new(3);
        let (_, view, _) = quantized_twin(&mut rng, 50, 8, 1, 4);
        let q = random_unit(&mut rng, 8);
        assert!(view.search(&q, 0).is_empty());
    }
}
