//! SIMD + query-blocked scoring kernels: the single scoring backend for
//! every scan in the system (§Perf).
//!
//! Every dot product on the serving path — flat scans, segment scans, IVF
//! centroid ranking and cell probing, baseline feature math — funnels
//! through one runtime-dispatched kernel: AVX2 on x86_64, NEON on
//! aarch64, and a portable fallback everywhere (including when forced via
//! `EAGLE_KERNEL=portable` or `[kernel] backend`).
//!
//! ## The bit-identity contract
//!
//! All backends implement the **same fixed reduction**: [`LANES`] = 8
//! partial sums, lane `l` accumulating elements `l, l+8, l+16, …` in
//! stream order with a rounded multiply then a rounded add per element
//! (deliberately *no* FMA contraction — a fused multiply-add rounds once
//! where the portable path rounds twice, which would break cross-backend
//! equality), tail elements `8·⌊n/8⌋ + t` folding into lane `t`, and a
//! final fixed pairwise tree `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`.
//! Per IEEE-754 every backend therefore produces **bit-identical** scores
//! — snapshot, scatter-gather, and IVF equivalence properties hold
//! unchanged no matter which backend the host dispatches to.
//!
//! ## Query-blocked scans
//!
//! [`Backend::scan_block_into`] scores a block of Q queries per pass over
//! a row slab, register-blocked in tiles of [`QUERY_TILE`] queries: each
//! row chunk is loaded once and multiplied against every query in the
//! tile, so corpus memory bandwidth is amortized across the batch like a
//! small GEMM. Blocking only reorders *independent* (query, row) pairs —
//! each pair still runs the fixed reduction above — so blocked scores are
//! bit-identical to single-query scores at every tile shape.
//!
//! ## Widening int8 kernels
//!
//! [`Backend::dot_i8`] / [`Backend::scan_i8_block_into`] are the SQ8
//! (scalar-quantized) analogues: i8×i8 products widened to i32 and
//! accumulated in i32. Integer accumulation is *exact* (|acc| ≤ dim·127²,
//! which fits i32 up to dim ≈ 130k), so every backend returns identical
//! accumulators by arithmetic alone — the fixed-reduction contract holds
//! trivially, and the quantized scan inherits all the equivalence
//! properties of the f32 path. See [`super::quant`] for the codebooks
//! that turn these accumulators into approximate scores.
//!
//! ## Dispatch
//!
//! [`active`] resolves once per process: the `EAGLE_KERNEL` env var
//! (`auto`/`portable`/`avx2`/`neon`) wins, then the configured default
//! ([`configure`], fed by the `[kernel]` config table), then CPU
//! detection. Forcing a backend the host lacks falls back to portable
//! with a warning rather than faulting.

use std::sync::OnceLock;

use super::topk::TopK;

/// Fixed partial-sum lane count shared by every backend.
pub const LANES: usize = 8;

/// Queries scored per register tile in the blocked scan.
pub const QUERY_TILE: usize = 4;

/// Rows scored per tile of the blocked scan before scores are flushed to
/// the per-query selectors; sized so a tile of rows (64 × 256 f32 =
/// 64 KiB) stays L2-resident while every query tile re-streams it.
pub const SCAN_BLOCK_ROWS: usize = 64;

/// A scoring backend. All variants exist on every architecture so config
/// handling is portable; [`Backend::available`] says whether the host can
/// actually run one, and the public entry points silently substitute
/// [`Backend::Portable`] for an unavailable choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Scalar fixed-lane reference; always available, and the
    /// bit-identity anchor the SIMD backends are tested against.
    Portable,
    /// 8-wide AVX2 (x86_64).
    Avx2,
    /// 2×4-wide NEON (aarch64).
    Neon,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Portable => "portable",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }

    /// Whether this backend can run on the current host.
    pub fn available(self) -> bool {
        match self {
            Backend::Portable => true,
            Backend::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    is_x86_feature_detected!("avx2")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            Backend::Neon => cfg!(target_arch = "aarch64"),
        }
    }

    /// This backend if the host supports it, otherwise portable.
    fn resolved(self) -> Backend {
        if self.available() {
            self
        } else {
            Backend::Portable
        }
    }

    /// Dot product under the fixed-reduction contract. Safe on any host:
    /// an unavailable backend computes via the portable path (same bits).
    #[inline]
    pub fn dot(self, a: &[f32], b: &[f32]) -> f32 {
        // hard assert: the SIMD paths trust the lengths with raw loads
        assert_eq!(a.len(), b.len(), "dot length mismatch");
        match self.resolved() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: resolved() verified AVX2 is present on this host.
            Backend::Avx2 => unsafe { avx2::dot(a, b) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is always present on aarch64.
            Backend::Neon => unsafe { neon::dot(a, b) },
            _ => portable::dot(a, b),
        }
    }

    /// Score a tile of queries against every row of a contiguous
    /// row-major slab: `out[q * n_rows + r] = dot(queries[q], row r)`,
    /// bit-identical to [`Backend::dot`] per pair. `rows.len()` must be a
    /// multiple of `dim` and `out` exactly `queries.len() * n_rows` long.
    pub fn scan_block_into(self, queries: &[&[f32]], dim: usize, rows: &[f32], out: &mut [f32]) {
        assert!(dim > 0, "dim must be positive");
        assert_eq!(rows.len() % dim, 0, "row slab not a multiple of dim");
        let n_rows = rows.len() / dim;
        assert_eq!(out.len(), queries.len() * n_rows, "out buffer size mismatch");
        for q in queries {
            assert_eq!(q.len(), dim, "query dim mismatch");
        }
        let backend = self.resolved();
        let mut qi = 0usize;
        while qi + QUERY_TILE <= queries.len() {
            let tile = [queries[qi], queries[qi + 1], queries[qi + 2], queries[qi + 3]];
            for r in 0..n_rows {
                let row = &rows[r * dim..(r + 1) * dim];
                let s = backend.dot_tile(&tile, row);
                for (t, &st) in s.iter().enumerate() {
                    out[(qi + t) * n_rows + r] = st;
                }
            }
            qi += QUERY_TILE;
        }
        for (q, query) in queries.iter().enumerate().skip(qi) {
            for r in 0..n_rows {
                out[q * n_rows + r] = backend.dot(query, &rows[r * dim..(r + 1) * dim]);
            }
        }
    }

    /// One register tile: [`QUERY_TILE`] queries against one row, the row
    /// chunk loaded once. Callers guarantee availability (`resolved`).
    #[inline]
    fn dot_tile(self, queries: &[&[f32]; QUERY_TILE], row: &[f32]) -> [f32; QUERY_TILE] {
        match self {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: callers resolve availability before the row loop.
            Backend::Avx2 => unsafe { avx2::dot_tile(queries, row) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is always present on aarch64.
            Backend::Neon => unsafe { neon::dot_tile(queries, row) },
            _ => portable::dot_tile(queries, row),
        }
    }

    /// Widening int8 dot: i8×i8 products taken in i32 and summed in i32.
    /// Exact integer arithmetic (no overflow up to dim ≈ 130k), so every
    /// backend returns the *same* accumulator — the SQ8 scan's
    /// bit-identity anchor. Safe on any host (unavailable backends fall
    /// back to portable, same value by exactness).
    #[inline]
    pub fn dot_i8(self, a: &[i8], b: &[i8]) -> i32 {
        // hard assert: the SIMD paths trust the lengths with raw loads
        assert_eq!(a.len(), b.len(), "dot_i8 length mismatch");
        match self.resolved() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: resolved() verified AVX2 is present on this host.
            Backend::Avx2 => unsafe { avx2::dot_i8(a, b) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is always present on aarch64.
            Backend::Neon => unsafe { neon::dot_i8(a, b) },
            _ => portable::dot_i8(a, b),
        }
    }

    /// Int8 analogue of [`Backend::scan_block_into`]: score a tile of
    /// quantized queries against every row of a contiguous i8 code slab,
    /// `out[q * n_rows + r] = dot_i8(queries[q], row r)`. Identical
    /// accumulators to per-pair [`Backend::dot_i8`] on every backend.
    pub fn scan_i8_block_into(self, queries: &[&[i8]], dim: usize, rows: &[i8], out: &mut [i32]) {
        assert!(dim > 0, "dim must be positive");
        assert_eq!(rows.len() % dim, 0, "code slab not a multiple of dim");
        let n_rows = rows.len() / dim;
        assert_eq!(out.len(), queries.len() * n_rows, "out buffer size mismatch");
        for q in queries {
            assert_eq!(q.len(), dim, "query dim mismatch");
        }
        let backend = self.resolved();
        let mut qi = 0usize;
        while qi + QUERY_TILE <= queries.len() {
            let tile = [queries[qi], queries[qi + 1], queries[qi + 2], queries[qi + 3]];
            for r in 0..n_rows {
                let row = &rows[r * dim..(r + 1) * dim];
                let s = backend.dot_i8_tile(&tile, row);
                for (t, &st) in s.iter().enumerate() {
                    out[(qi + t) * n_rows + r] = st;
                }
            }
            qi += QUERY_TILE;
        }
        for (q, query) in queries.iter().enumerate().skip(qi) {
            for r in 0..n_rows {
                out[q * n_rows + r] = backend.dot_i8(query, &rows[r * dim..(r + 1) * dim]);
            }
        }
    }

    /// One int8 register tile: [`QUERY_TILE`] quantized queries against
    /// one code row, the row loaded once. Callers guarantee availability.
    #[inline]
    fn dot_i8_tile(self, queries: &[&[i8]; QUERY_TILE], row: &[i8]) -> [i32; QUERY_TILE] {
        match self {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: callers resolve availability before the row loop.
            Backend::Avx2 => unsafe { avx2::dot_i8_tile(queries, row) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is always present on aarch64.
            Backend::Neon => unsafe { neon::dot_i8_tile(queries, row) },
            _ => portable::dot_i8_tile(queries, row),
        }
    }
}

/// The fixed pairwise reduction tree every backend finishes with.
#[inline]
fn reduce_lanes(l: [f32; LANES]) -> f32 {
    ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
}

/// Fold the tail (`n % LANES` trailing elements) into the lane array,
/// element `t` into lane `t` — shared by every backend so tails are
/// bit-identical too.
#[inline]
fn add_tail(lanes: &mut [f32; LANES], a: &[f32], b: &[f32], from: usize) {
    for (t, i) in (from..a.len()).enumerate() {
        lanes[t] += a[i] * b[i];
    }
}

mod portable {
    use super::{add_tail, reduce_lanes, LANES, QUERY_TILE};

    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        let mut lanes = [0.0f32; LANES];
        let mut ca = a.chunks_exact(LANES);
        let mut cb = b.chunks_exact(LANES);
        for (xa, xb) in (&mut ca).zip(&mut cb) {
            for l in 0..LANES {
                lanes[l] += xa[l] * xb[l];
            }
        }
        add_tail(&mut lanes, a, b, a.len() - ca.remainder().len());
        reduce_lanes(lanes)
    }

    pub fn dot_tile(queries: &[&[f32]; QUERY_TILE], row: &[f32]) -> [f32; QUERY_TILE] {
        let n = row.len();
        let chunks = n / LANES;
        let mut lanes = [[0.0f32; LANES]; QUERY_TILE];
        for c in 0..chunks {
            let i = c * LANES;
            let rv = &row[i..i + LANES];
            for (t, q) in queries.iter().enumerate() {
                let qv = &q[i..i + LANES];
                for l in 0..LANES {
                    lanes[t][l] += qv[l] * rv[l];
                }
            }
        }
        let mut out = [0.0f32; QUERY_TILE];
        for (t, q) in queries.iter().enumerate() {
            add_tail(&mut lanes[t], q, row, chunks * LANES);
            out[t] = reduce_lanes(lanes[t]);
        }
        out
    }

    pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
        let mut acc = 0i32;
        for (&x, &y) in a.iter().zip(b) {
            acc += x as i32 * y as i32;
        }
        acc
    }

    pub fn dot_i8_tile(queries: &[&[i8]; QUERY_TILE], row: &[i8]) -> [i32; QUERY_TILE] {
        let mut out = [0i32; QUERY_TILE];
        for (i, &r) in row.iter().enumerate() {
            let rv = r as i32;
            for (t, q) in queries.iter().enumerate() {
                out[t] += q[i] as i32 * rv;
            }
        }
        out
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::{
        __m128i, __m256i, _mm256_add_epi32, _mm256_add_ps, _mm256_cvtepi8_epi16,
        _mm256_loadu_ps, _mm256_madd_epi16, _mm256_mul_ps, _mm256_setzero_ps,
        _mm256_setzero_si256, _mm256_storeu_ps, _mm256_storeu_si256, _mm_loadu_si128,
    };

    use super::{add_tail, reduce_lanes, LANES, QUERY_TILE};

    /// i8 elements per int8 inner-loop step (one 128-bit load, widened).
    const I8_STEP: usize = 16;

    /// # Safety
    /// Requires AVX2 on the running CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / LANES;
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let i = c * LANES;
            let va = _mm256_loadu_ps(a.as_ptr().add(i));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i));
            // mul then add (NOT fmadd): keeps per-lane rounding identical
            // to the portable path — see the module bit-identity contract
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        add_tail(&mut lanes, a, b, chunks * LANES);
        reduce_lanes(lanes)
    }

    /// # Safety
    /// Requires AVX2 on the running CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_tile(queries: &[&[f32]; QUERY_TILE], row: &[f32]) -> [f32; QUERY_TILE] {
        let n = row.len();
        let chunks = n / LANES;
        let mut acc = [_mm256_setzero_ps(); QUERY_TILE];
        for c in 0..chunks {
            let i = c * LANES;
            let rv = _mm256_loadu_ps(row.as_ptr().add(i));
            for (t, q) in queries.iter().enumerate() {
                let qv = _mm256_loadu_ps(q.as_ptr().add(i));
                acc[t] = _mm256_add_ps(acc[t], _mm256_mul_ps(qv, rv));
            }
        }
        let mut out = [0.0f32; QUERY_TILE];
        for (t, q) in queries.iter().enumerate() {
            let mut lanes = [0.0f32; LANES];
            _mm256_storeu_ps(lanes.as_mut_ptr(), acc[t]);
            add_tail(&mut lanes, q, row, chunks * LANES);
            out[t] = reduce_lanes(lanes);
        }
        out
    }

    /// Widen 16 i8 lanes to i16 (sign-extended) from an unaligned load.
    ///
    /// # Safety
    /// `p` must be readable for 16 bytes; requires AVX2.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn widen16(p: *const i8) -> __m256i {
        _mm256_cvtepi8_epi16(_mm_loadu_si128(p as *const __m128i))
    }

    /// Sum the 8 i32 lanes of an accumulator plus a scalar tail. Exact,
    /// so the summation order is immaterial.
    ///
    /// # Safety
    /// Requires AVX2.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn finish_i8(acc: __m256i, a: &[i8], b: &[i8], from: usize) -> i32 {
        let mut lanes = [0i32; LANES];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
        let mut sum: i32 = lanes.iter().sum();
        for i in from..a.len() {
            sum += a[i] as i32 * b[i] as i32;
        }
        sum
    }

    /// # Safety
    /// Requires AVX2 on the running CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
        let chunks = a.len() / I8_STEP;
        let mut acc = _mm256_setzero_si256();
        for c in 0..chunks {
            let i = c * I8_STEP;
            let va = widen16(a.as_ptr().add(i));
            let vb = widen16(b.as_ptr().add(i));
            // madd: i16×i16 products pairwise-summed straight into i32 —
            // no saturation is reachable (|p0 + p1| ≤ 2·127² < 2^15·2)
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(va, vb));
        }
        finish_i8(acc, a, b, chunks * I8_STEP)
    }

    /// # Safety
    /// Requires AVX2 on the running CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_i8_tile(queries: &[&[i8]; QUERY_TILE], row: &[i8]) -> [i32; QUERY_TILE] {
        let chunks = row.len() / I8_STEP;
        let mut acc = [_mm256_setzero_si256(); QUERY_TILE];
        for c in 0..chunks {
            let i = c * I8_STEP;
            let rv = widen16(row.as_ptr().add(i));
            for (t, q) in queries.iter().enumerate() {
                let qv = widen16(q.as_ptr().add(i));
                acc[t] = _mm256_add_epi32(acc[t], _mm256_madd_epi16(qv, rv));
            }
        }
        let mut out = [0i32; QUERY_TILE];
        for (t, q) in queries.iter().enumerate() {
            out[t] = finish_i8(acc[t], q, row, chunks * I8_STEP);
        }
        out
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::{
        int32x4_t, vaddq_f32, vaddvq_s32, vdupq_n_f32, vdupq_n_s32, vget_high_s8, vget_low_s8,
        vld1q_f32, vld1q_s8, vmull_s8, vmulq_f32, vpadalq_s16, vst1q_f32,
    };

    use super::{add_tail, reduce_lanes, LANES, QUERY_TILE};

    /// i8 elements per int8 inner-loop step (one 128-bit load).
    const I8_STEP: usize = 16;

    /// # Safety
    /// Requires NEON (always present on aarch64).
    #[target_feature(enable = "neon")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / LANES;
        // lanes 0-3 in acc0, 4-7 in acc1 — same lane mapping as portable
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        for c in 0..chunks {
            let i = c * LANES;
            let a0 = vld1q_f32(a.as_ptr().add(i));
            let a1 = vld1q_f32(a.as_ptr().add(i + 4));
            let b0 = vld1q_f32(b.as_ptr().add(i));
            let b1 = vld1q_f32(b.as_ptr().add(i + 4));
            // mul then add (NOT vfmaq): keeps rounding identical to the
            // portable path — see the module bit-identity contract
            acc0 = vaddq_f32(acc0, vmulq_f32(a0, b0));
            acc1 = vaddq_f32(acc1, vmulq_f32(a1, b1));
        }
        let mut lanes = [0.0f32; LANES];
        vst1q_f32(lanes.as_mut_ptr(), acc0);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc1);
        add_tail(&mut lanes, a, b, chunks * LANES);
        reduce_lanes(lanes)
    }

    /// # Safety
    /// Requires NEON (always present on aarch64).
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_tile(queries: &[&[f32]; QUERY_TILE], row: &[f32]) -> [f32; QUERY_TILE] {
        let n = row.len();
        let chunks = n / LANES;
        let mut acc0 = [vdupq_n_f32(0.0); QUERY_TILE];
        let mut acc1 = [vdupq_n_f32(0.0); QUERY_TILE];
        for c in 0..chunks {
            let i = c * LANES;
            let r0 = vld1q_f32(row.as_ptr().add(i));
            let r1 = vld1q_f32(row.as_ptr().add(i + 4));
            for (t, q) in queries.iter().enumerate() {
                let q0 = vld1q_f32(q.as_ptr().add(i));
                let q1 = vld1q_f32(q.as_ptr().add(i + 4));
                acc0[t] = vaddq_f32(acc0[t], vmulq_f32(q0, r0));
                acc1[t] = vaddq_f32(acc1[t], vmulq_f32(q1, r1));
            }
        }
        let mut out = [0.0f32; QUERY_TILE];
        for (t, q) in queries.iter().enumerate() {
            let mut lanes = [0.0f32; LANES];
            vst1q_f32(lanes.as_mut_ptr(), acc0[t]);
            vst1q_f32(lanes.as_mut_ptr().add(4), acc1[t]);
            add_tail(&mut lanes, q, row, chunks * LANES);
            out[t] = reduce_lanes(lanes);
        }
        out
    }

    /// Accumulate one 16-element i8 chunk of `a·b` into `acc`: widening
    /// multiplies (i8×i8 → i16) pairwise-accumulated into i32 lanes.
    /// Exact integer arithmetic throughout.
    ///
    /// # Safety
    /// `a` and `b` must be readable for 16 bytes; requires NEON.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn madd16_i8(acc: int32x4_t, a: *const i8, b: *const i8) -> int32x4_t {
        let va = vld1q_s8(a);
        let vb = vld1q_s8(b);
        let acc = vpadalq_s16(acc, vmull_s8(vget_low_s8(va), vget_low_s8(vb)));
        vpadalq_s16(acc, vmull_s8(vget_high_s8(va), vget_high_s8(vb)))
    }

    /// # Safety
    /// Requires NEON (always present on aarch64).
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
        let chunks = a.len() / I8_STEP;
        let mut acc = vdupq_n_s32(0);
        for c in 0..chunks {
            let i = c * I8_STEP;
            acc = madd16_i8(acc, a.as_ptr().add(i), b.as_ptr().add(i));
        }
        let mut sum = vaddvq_s32(acc);
        for i in chunks * I8_STEP..a.len() {
            sum += a[i] as i32 * b[i] as i32;
        }
        sum
    }

    /// # Safety
    /// Requires NEON (always present on aarch64).
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_i8_tile(queries: &[&[i8]; QUERY_TILE], row: &[i8]) -> [i32; QUERY_TILE] {
        let chunks = row.len() / I8_STEP;
        let mut acc = [vdupq_n_s32(0); QUERY_TILE];
        for c in 0..chunks {
            let i = c * I8_STEP;
            let rp = row.as_ptr().add(i);
            for (t, q) in queries.iter().enumerate() {
                acc[t] = madd16_i8(acc[t], q.as_ptr().add(i), rp);
            }
        }
        let mut out = [0i32; QUERY_TILE];
        for (t, q) in queries.iter().enumerate() {
            let mut sum = vaddvq_s32(acc[t]);
            for i in chunks * I8_STEP..row.len() {
                sum += q[i] as i32 * row[i] as i32;
            }
            out[t] = sum;
        }
        out
    }
}

/// Best backend the host supports.
pub fn detect() -> Backend {
    if Backend::Avx2.available() {
        return Backend::Avx2;
    }
    if Backend::Neon.available() {
        return Backend::Neon;
    }
    Backend::Portable
}

/// Parse a backend choice string; `Ok(None)` means auto-detect.
pub fn parse_choice(s: &str) -> Result<Option<Backend>, String> {
    match s.trim().to_ascii_lowercase().as_str() {
        "" | "auto" => Ok(None),
        "portable" => Ok(Some(Backend::Portable)),
        "avx2" => Ok(Some(Backend::Avx2)),
        "neon" => Ok(Some(Backend::Neon)),
        other => Err(format!(
            "unknown kernel backend '{other}' (expected auto|portable|avx2|neon)"
        )),
    }
}

static ACTIVE: OnceLock<Backend> = OnceLock::new();
static CONFIGURED: OnceLock<Backend> = OnceLock::new();

/// Install the configured default backend (the `[kernel] backend` config
/// key). The `EAGLE_KERNEL` env var overrides this, and a call after the
/// first scoring op cannot change the already-resolved backend — call it
/// at process startup, before serving. A request that can no longer take
/// effect (dispatch already resolved differently, or an earlier call
/// configured a different default) warns instead of failing: scores are
/// bit-identical on every backend, so only performance is at stake.
pub fn configure(choice: &str) -> Result<(), String> {
    let Some(b) = parse_choice(choice)? else {
        return Ok(());
    };
    let _ = CONFIGURED.set(b);
    if let Some(&active) = ACTIVE.get() {
        if active != b.resolved() {
            eprintln!(
                "warning: scoring kernel already resolved to '{}' (env override or \
                 prior use); configured '{}' takes no effect in this process",
                active.name(),
                b.name()
            );
        }
    } else if CONFIGURED.get() != Some(&b) {
        eprintln!(
            "warning: scoring kernel default already configured to '{}'; '{}' ignored",
            CONFIGURED.get().map_or("?", |c| c.name()),
            b.name()
        );
    }
    Ok(())
}

/// The process-wide backend, resolved once: `EAGLE_KERNEL` env override,
/// else the configured default, else CPU detection. Unknown names warn
/// and keep the configured default (the shared
/// [`crate::config::env_override`] rule); unavailable backends warn and
/// fall back to portable.
pub fn active() -> Backend {
    *ACTIVE.get_or_init(|| {
        let choice = crate::config::env_override(
            "EAGLE_KERNEL",
            "[kernel] backend",
            CONFIGURED.get().copied(),
            parse_choice,
        );
        match choice {
            Some(b) if b.available() => b,
            Some(b) => {
                eprintln!(
                    "warning: kernel backend '{}' unavailable on this host; using portable",
                    b.name()
                );
                Backend::Portable
            }
            None => detect(),
        }
    })
}

/// Dot product through the active backend (the scan hot loop).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    active().dot(a, b)
}

/// A plain single-dot kernel entry point.
pub type DotFn = fn(&[f32], &[f32]) -> f32;

fn portable_entry(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "dot length mismatch");
    portable::dot(a, b)
}

#[cfg(target_arch = "x86_64")]
fn avx2_entry(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "dot length mismatch");
    // SAFETY: this entry is only ever handed out by `dot_fn` after
    // `active()` verified AVX2 is present on this host.
    unsafe { avx2::dot(a, b) }
}

#[cfg(target_arch = "aarch64")]
fn neon_entry(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "dot length mismatch");
    // SAFETY: NEON is always present on aarch64.
    unsafe { neon::dot(a, b) }
}

/// The active backend's dot kernel as a plain fn pointer: resolve once,
/// then per-row calls skip even the availability re-check that
/// [`Backend::dot`] pays on every call. This is what the single-query
/// scan loops hold.
pub fn dot_fn() -> DotFn {
    match active() {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => avx2_entry,
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => neon_entry,
        _ => portable_entry,
    }
}

/// Blocked multi-query scan of a contiguous row slab into per-query
/// selectors, [`SCAN_BLOCK_ROWS`] rows per tile: scores land in `tile`
/// (kernel scratch, reused across calls) and are pushed as
/// `(id_base + row, score)` in ascending row order per query — identical
/// push order to a per-row scalar scan, so TopK tie-breaks are unchanged.
pub(crate) fn scan_rows_into(
    queries: &[&[f32]],
    dim: usize,
    rows: &[f32],
    id_base: u32,
    topks: &mut [TopK],
    tile: &mut Vec<f32>,
) {
    debug_assert_eq!(queries.len(), topks.len(), "query/selector count mismatch");
    let backend = active();
    let n_rows = rows.len() / dim;
    debug_assert_eq!(rows.len(), n_rows * dim);
    let mut start = 0usize;
    while start < n_rows {
        let block = (n_rows - start).min(SCAN_BLOCK_ROWS);
        tile.clear();
        tile.resize(queries.len() * block, 0.0);
        backend.scan_block_into(
            queries,
            dim,
            &rows[start * dim..(start + block) * dim],
            tile.as_mut_slice(),
        );
        for (q, topk) in topks.iter_mut().enumerate() {
            for (r, &s) in tile[q * block..(q + 1) * block].iter().enumerate() {
                topk.push(id_base + (start + r) as u32, s);
            }
        }
        start += block;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Rng};

    fn backends() -> Vec<Backend> {
        let mut all = vec![Backend::Portable];
        for b in [Backend::Avx2, Backend::Neon] {
            if b.available() {
                all.push(b);
            }
        }
        all
    }

    #[test]
    fn names_and_parse_roundtrip() {
        for b in [Backend::Portable, Backend::Avx2, Backend::Neon] {
            assert_eq!(parse_choice(b.name()), Ok(Some(b)));
        }
        assert_eq!(parse_choice("auto"), Ok(None));
        assert_eq!(parse_choice(""), Ok(None));
        assert_eq!(parse_choice("  AVX2 "), Ok(Some(Backend::Avx2)));
        assert!(parse_choice("sse9").is_err());
    }

    #[test]
    fn detect_is_available_and_active_is_resolvable() {
        assert!(detect().available());
        assert!(active().available());
        assert!(Backend::Portable.available());
    }

    #[test]
    fn unavailable_backend_resolves_to_portable() {
        // on any single host at least one of avx2/neon is foreign
        for b in [Backend::Avx2, Backend::Neon] {
            if !b.available() {
                // must compute (via portable), not fault
                assert_eq!(b.dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
            }
        }
    }

    #[test]
    fn portable_dot_matches_naive_within_tolerance() {
        prop::check("kernel portable ~= naive", 120, |rng| {
            let n = rng.below(70);
            let a = prop::vec_f32(rng, n);
            let b = prop::vec_f32(rng, n);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            prop::assert_close(
                Backend::Portable.dot(&a, &b) as f64,
                naive as f64,
                1e-4,
                "dot",
            )
        });
    }

    #[test]
    fn all_backends_bit_identical_to_portable() {
        // the contract the snapshot-equivalence suite rides on: random
        // dims, including every tail residue and large magnitudes
        prop::check("simd == portable bitwise", 200, |rng| {
            let n = match rng.below(4) {
                0 => rng.below(17),            // tiny + every tail residue
                1 => 8 * (1 + rng.below(40)),  // exact multiples of LANES
                2 => 255 + rng.below(4),       // around the serving dim
                _ => 1 + rng.below(700),       // broad
            };
            let scale = [1.0f32, 1e-4, 1e4][rng.below(3)];
            let a: Vec<f32> = prop::vec_f32(rng, n).iter().map(|x| x * scale).collect();
            let b = prop::vec_f32(rng, n);
            let want = Backend::Portable.dot(&a, &b);
            for backend in backends() {
                let got = backend.dot(&a, &b);
                prop::assert_prop(
                    got.to_bits() == want.to_bits(),
                    &format!("{} diverged: {got} vs portable {want} at n={n}", backend.name()),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn blocked_scan_bit_identical_to_single_dots() {
        prop::check("scan_block == dot grid", 60, |rng| {
            let dim = 1 + rng.below(80);
            let n_rows = rng.below(30);
            let n_q = rng.below(11);
            let rows = prop::vec_f32(rng, n_rows * dim);
            let queries: Vec<Vec<f32>> = (0..n_q).map(|_| prop::vec_f32(rng, dim)).collect();
            let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
            for backend in backends() {
                let mut out = vec![0.0f32; n_q * n_rows];
                backend.scan_block_into(&qrefs, dim, &rows, &mut out);
                for (q, query) in qrefs.iter().enumerate() {
                    for r in 0..n_rows {
                        let want = Backend::Portable.dot(query, &rows[r * dim..(r + 1) * dim]);
                        let got = out[q * n_rows + r];
                        prop::assert_prop(
                            got.to_bits() == want.to_bits(),
                            &format!("{} blocked (q{q},r{r}): {got} != {want}", backend.name()),
                        )?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn scan_rows_into_matches_per_row_pushes() {
        let mut rng = Rng::new(0x5CA7);
        let dim = 24;
        let n_rows = 3 * SCAN_BLOCK_ROWS + 7; // exercise multiple tiles + ragged last
        let rows = prop::vec_f32(&mut rng, n_rows * dim);
        let queries: Vec<Vec<f32>> = (0..6).map(|_| prop::vec_f32(&mut rng, dim)).collect();
        let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        let mut topks: Vec<TopK> = (0..qrefs.len()).map(|_| TopK::new(9)).collect();
        let mut tile = Vec::new();
        scan_rows_into(&qrefs, dim, &rows, 100, &mut topks, &mut tile);
        for (q, topk) in topks.into_iter().enumerate() {
            let mut reference = TopK::new(9);
            for r in 0..n_rows {
                reference.push(100 + r as u32, dot(&queries[q], &rows[r * dim..(r + 1) * dim]));
            }
            assert_eq!(topk.into_sorted(), reference.into_sorted(), "query {q}");
        }
    }

    #[test]
    fn empty_inputs_are_fine() {
        assert_eq!(dot(&[], &[]), 0.0);
        for backend in backends() {
            let mut out = [0.0f32; 0];
            backend.scan_block_into(&[], 4, &[], &mut out);
            let q: &[f32] = &[1.0, 0.0, 0.0, 0.0];
            let mut out1 = [0.0f32; 0];
            backend.scan_block_into(&[q], 4, &[], &mut out1);
            assert_eq!(backend.dot_i8(&[], &[]), 0);
            let mut iout = [0i32; 0];
            backend.scan_i8_block_into(&[], 4, &[], &mut iout);
        }
    }

    fn vec_i8(rng: &mut Rng, n: usize) -> Vec<i8> {
        (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect()
    }

    #[test]
    fn i8_dot_exact_on_every_backend() {
        // the int8 contract: i32 accumulation is exact, so every backend
        // must equal the i64-checked naive sum *exactly* — full-range
        // codes, every tail residue of the 16-lane inner step
        prop::check("dot_i8 == naive i64", 200, |rng| {
            let n = match rng.below(3) {
                0 => rng.below(33),           // tiny + every tail residue
                1 => 16 * (1 + rng.below(32)), // exact multiples of the step
                _ => 1 + rng.below(600),       // broad
            };
            let a = vec_i8(rng, n);
            let b = vec_i8(rng, n);
            let want: i64 = a.iter().zip(&b).map(|(&x, &y)| x as i64 * y as i64).sum();
            for backend in backends() {
                let got = backend.dot_i8(&a, &b);
                prop::assert_prop(
                    got as i64 == want,
                    &format!("{} dot_i8: {got} != {want} at n={n}", backend.name()),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn i8_blocked_scan_matches_single_dots() {
        prop::check("scan_i8_block == dot_i8 grid", 60, |rng| {
            let dim = 1 + rng.below(80);
            let n_rows = rng.below(30);
            let n_q = rng.below(11);
            let rows = vec_i8(rng, n_rows * dim);
            let queries: Vec<Vec<i8>> = (0..n_q).map(|_| vec_i8(rng, dim)).collect();
            let qrefs: Vec<&[i8]> = queries.iter().map(|q| q.as_slice()).collect();
            for backend in backends() {
                let mut out = vec![0i32; n_q * n_rows];
                backend.scan_i8_block_into(&qrefs, dim, &rows, &mut out);
                for (q, query) in qrefs.iter().enumerate() {
                    for r in 0..n_rows {
                        let want = Backend::Portable.dot_i8(query, &rows[r * dim..(r + 1) * dim]);
                        let got = out[q * n_rows + r];
                        prop::assert_prop(
                            got == want,
                            &format!("{} i8 blocked (q{q},r{r}): {got} != {want}", backend.name()),
                        )?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn dot_fn_matches_active_dot_bitwise() {
        let f = dot_fn();
        let mut rng = Rng::new(0xD07);
        for _ in 0..50 {
            let n = rng.below(300);
            let a = prop::vec_f32(&mut rng, n);
            let b = prop::vec_f32(&mut rng, n);
            assert_eq!(f(&a, &b).to_bits(), dot(&a, &b).to_bits());
        }
    }

    #[test]
    fn configure_accepts_known_rejects_unknown() {
        // ACTIVE may already be resolved by other tests — configure must
        // still validate names without disturbing it
        assert!(configure("auto").is_ok());
        assert!(configure("portable").is_ok());
        assert!(configure("warp9").is_err());
    }
}
