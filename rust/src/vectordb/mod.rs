//! Vector database: Eagle's store of historical prompt embeddings and their
//! pairwise feedback payloads.
//!
//! On every request Eagle-Local retrieves the N nearest historical prompts
//! by cosine similarity (embeddings are L2-normalized, so dot product ==
//! cosine) and replays their feedback through a locally-seeded ELO engine.
//!
//! Two index implementations behind [`VectorIndex`]:
//! - [`flat::FlatStore`] — exact blocked scan; the default for the corpus
//!   sizes RouterBench produces (thousands of entries).
//! - [`ivf::IvfIndex`] — inverted-file (k-means coarse quantizer) ANN for
//!   larger stores; probes `nprobe` nearest cells.
//!
//! Online inserts are O(1) amortized on both paths (IVF assigns new vectors
//! to their nearest existing centroid) — required for the paper's real-time
//! adaptation claim.
//!
//! All scan scoring funnels through the runtime-dispatched SIMD kernels in
//! [`kernel`] (AVX2 / NEON / portable, bit-identical by construction);
//! batched searches use its query-blocked scans via
//! [`ReadIndex::search_batch_into`] so corpus bandwidth is amortized
//! across a batch. For bandwidth-bound corpora, [`quant`] layers an SQ8
//! scalar-quantized scan (1 byte/element streamed through widening int8
//! kernels) with an exact rerank tail over the same views.

pub mod flat;
pub mod ivf;
pub mod kernel;
pub mod quant;
pub mod topk;
pub mod view;

use crate::elo::Comparison;
use self::topk::TopK;

/// Payload attached to each stored vector: every pairwise feedback record
/// collected for that prompt (paper workflow step 5). One stored vector per
/// prompt; a retrieved neighbor contributes all of its comparisons to the
/// local ELO replay.
#[derive(Debug, Clone, PartialEq)]
pub struct Feedback {
    pub comparisons: Vec<Comparison>,
}

impl Feedback {
    pub fn single(comparison: Comparison) -> Self {
        Feedback { comparisons: vec![comparison] }
    }
}

/// A search hit: entry id + cosine score.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hit {
    pub id: u32,
    pub score: f32,
}

/// Reusable scratch for query-blocked batch searches: one [`TopK`]
/// selector per query plus the kernel score tile, allocated once and
/// recycled across batches (the route path's per-query-allocation
/// killer). Views push candidates into the selectors; callers drain the
/// per-query hits out afterwards.
#[derive(Debug, Default)]
pub struct BatchTopK {
    topks: Vec<TopK>,
    tile: Vec<f32>,
}

impl BatchTopK {
    pub fn new() -> Self {
        BatchTopK::default()
    }

    /// Reset for a batch of `n_queries` selectors of capacity `k`,
    /// keeping every allocation.
    pub fn begin(&mut self, n_queries: usize, k: usize) {
        self.topks.truncate(n_queries);
        for t in &mut self.topks {
            t.reset(k);
        }
        while self.topks.len() < n_queries {
            self.topks.push(TopK::new(k));
        }
    }

    /// The per-query selectors of the current batch.
    pub fn selectors_mut(&mut self) -> &mut [TopK] {
        &mut self.topks
    }

    /// Selectors and the kernel score tile, borrowed together (blocked
    /// scans fill the tile and push rows into the selectors).
    pub(crate) fn parts_mut(&mut self) -> (&mut [TopK], &mut Vec<f32>) {
        (&mut self.topks, &mut self.tile)
    }

    /// Drain each query's sorted hits into `out`, reusing its inner
    /// buffers; `out` ends up with exactly one hit list per query.
    pub fn drain_hits_into(&mut self, out: &mut Vec<Vec<Hit>>) {
        out.truncate(self.topks.len());
        while out.len() < self.topks.len() {
            out.push(Vec::new());
        }
        for (t, hits) in self.topks.iter_mut().zip(out.iter_mut()) {
            hits.clear();
            t.drain_sorted(|id, score| hits.push(Hit { id, score }));
        }
    }
}

/// The read-only surface of an index: everything the scoring path needs
/// and nothing the ingest path has. Snapshot views ([`view::FrozenView`],
/// [`ivf::IvfView`]) implement only this; full indexes implement the
/// [`VectorIndex`] extension on top. Scoring code written against
/// `ReadIndex` runs unchanged over a mutable store or an immutable
/// snapshot view.
pub trait ReadIndex {
    /// Dimensionality of stored vectors.
    fn dim(&self) -> usize;

    /// Number of visible vectors.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The k nearest visible vectors by dot product, best first.
    fn search(&self, query: &[f32], k: usize) -> Vec<Hit>;

    /// Top-k for a whole batch of queries against one consistent view,
    /// pushed into `acc`'s per-query selectors. The default maps the
    /// single-query [`ReadIndex::search`]; bulk views override it with
    /// query-blocked kernel scans ([`kernel`]) that amortize corpus
    /// bandwidth across the batch. Either way the retained hits are
    /// bit-identical to `queries.len()` single searches.
    fn search_batch_into(&self, queries: &[&[f32]], k: usize, acc: &mut BatchTopK) {
        acc.begin(queries.len(), k);
        for (query, topk) in queries.iter().zip(acc.selectors_mut()) {
            for h in self.search(query, k) {
                topk.push(h.id, h.score);
            }
        }
    }

    /// Convenience wrapper over [`ReadIndex::search_batch_into`]
    /// allocating fresh hit lists (tests, one-shot callers).
    fn search_batch(&self, queries: &[&[f32]], k: usize) -> Vec<Vec<Hit>> {
        let mut acc = BatchTopK::new();
        let mut out = Vec::new();
        self.search_batch_into(queries, k, &mut acc);
        acc.drain_hits_into(&mut out);
        out
    }

    /// Feedback payload for an entry id.
    fn feedback(&self, id: u32) -> &Feedback;

    /// Stored vector for an entry id.
    fn vector(&self, id: u32) -> &[f32];
}

/// Common interface over exact and approximate *writable* indexes.
pub trait VectorIndex: ReadIndex {
    /// Insert a vector (assumed L2-normalized) with its feedback payload;
    /// returns its id.
    fn add(&mut self, vector: &[f32], feedback: Feedback) -> u32;
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::elo::{Comparison, Outcome};
    use crate::util::{l2_normalize, Rng};

    pub fn random_unit(rng: &mut Rng, dim: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        l2_normalize(&mut v);
        v
    }

    pub fn dummy_feedback(i: usize) -> Feedback {
        Feedback::single(Comparison {
            a: i % 3,
            b: (i + 1) % 3 + if i % 3 == (i + 1) % 3 { 1 } else { 0 },
            outcome: if i % 2 == 0 { Outcome::WinA } else { Outcome::WinB },
        })
    }

    /// Exact brute-force reference search.
    pub fn naive_search(
        vectors: &[Vec<f32>],
        query: &[f32],
        k: usize,
    ) -> Vec<(u32, f32)> {
        let mut scored: Vec<(u32, f32)> = vectors
            .iter()
            .enumerate()
            .map(|(i, v)| {
                (i as u32, v.iter().zip(query).map(|(a, b)| a * b).sum::<f32>())
            })
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        scored.truncate(k);
        scored
    }
}
