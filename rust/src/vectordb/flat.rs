//! Exact flat (brute-force) vector index with a kernel-backed scan.
//!
//! Vectors live in one contiguous row-major matrix; scans stream it
//! through the dispatched SIMD kernels ([`super::kernel`]) and feed a
//! bounded [`TopK`]. Batched searches go through the query-blocked kernel
//! so corpus bandwidth is amortized across the batch. For the corpus
//! sizes RouterBench yields (10^3–10^4 entries at D=256) an exact scan is
//! faster than any index — this is the default request-path store
//! (§Perf).

use super::kernel;
use super::topk::TopK;
use super::{BatchTopK, Feedback, Hit, ReadIndex, VectorIndex};

/// Exact flat store.
#[derive(Debug, Clone)]
pub struct FlatStore {
    dim: usize,
    data: Vec<f32>,
    payloads: Vec<Feedback>,
}

impl FlatStore {
    pub fn new(dim: usize) -> Self {
        assert!(dim > 0, "dim must be positive");
        FlatStore { dim, data: Vec::new(), payloads: Vec::new() }
    }

    /// Pre-allocate for `capacity` vectors.
    pub fn with_capacity(dim: usize, capacity: usize) -> Self {
        let mut s = Self::new(dim);
        s.data.reserve(capacity * dim);
        s.payloads.reserve(capacity);
        s
    }

    /// Raw row access (used by the IVF builder).
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Scan scoring into a caller-provided TopK (allocation-free reuse).
    pub fn search_into(&self, query: &[f32], topk: &mut TopK) {
        assert_eq!(query.len(), self.dim, "query dim mismatch");
        // resolve the kernel dispatch once for the whole scan
        let dot = kernel::dot_fn();
        for i in 0..self.payloads.len() {
            topk.push(i as u32, dot(self.row(i), query));
        }
    }

    /// Dot product of the query against every stored row (dense scores).
    /// Used by tests and by the HLO-scorer agreement checks.
    pub fn score_all(&self, query: &[f32]) -> Vec<f32> {
        assert_eq!(query.len(), self.dim);
        let dot = kernel::dot_fn();
        (0..self.payloads.len())
            .map(|i| dot(self.row(i), query))
            .collect()
    }
}

impl ReadIndex for FlatStore {
    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.payloads.len()
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        let mut topk = TopK::new(k);
        self.search_into(query, &mut topk);
        topk.into_sorted()
            .into_iter()
            .map(|(id, score)| Hit { id, score })
            .collect()
    }

    fn search_batch_into(&self, queries: &[&[f32]], k: usize, acc: &mut BatchTopK) {
        for q in queries {
            assert_eq!(q.len(), self.dim, "query dim mismatch");
        }
        acc.begin(queries.len(), k);
        let (topks, tile) = acc.parts_mut();
        kernel::scan_rows_into(queries, self.dim, &self.data, 0, topks, tile);
    }

    fn feedback(&self, id: u32) -> &Feedback {
        &self.payloads[id as usize]
    }

    fn vector(&self, id: u32) -> &[f32] {
        self.row(id as usize)
    }
}

impl VectorIndex for FlatStore {
    fn add(&mut self, vector: &[f32], feedback: Feedback) -> u32 {
        assert_eq!(vector.len(), self.dim, "vector dim mismatch");
        let id = self.payloads.len() as u32;
        self.data.extend_from_slice(vector);
        self.payloads.push(feedback);
        id
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;
    use crate::util::{prop, Rng};

    #[test]
    fn add_and_len() {
        let mut s = FlatStore::new(4);
        assert!(s.is_empty());
        let id = s.add(&[1.0, 0.0, 0.0, 0.0], dummy_feedback(0));
        assert_eq!(id, 0);
        assert_eq!(s.len(), 1);
        assert_eq!(s.vector(0), &[1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "dim mismatch")]
    fn rejects_wrong_dim() {
        let mut s = FlatStore::new(4);
        s.add(&[1.0, 0.0], dummy_feedback(0));
    }

    #[test]
    fn search_exact_match_first() {
        let mut rng = Rng::new(1);
        let mut s = FlatStore::new(16);
        let mut vectors = Vec::new();
        for i in 0..50 {
            let v = random_unit(&mut rng, 16);
            s.add(&v, dummy_feedback(i));
            vectors.push(v);
        }
        let hits = s.search(&vectors[17], 5);
        assert_eq!(hits[0].id, 17);
        assert!((hits[0].score - 1.0).abs() < 1e-5);
    }

    #[test]
    fn search_matches_naive_reference() {
        prop::check("flat == naive", 60, |rng| {
            let dim = [8, 16, 256][rng.below(3)];
            let n = 1 + rng.below(300);
            let k = 1 + rng.below(25);
            let mut s = FlatStore::new(dim);
            let mut vectors = Vec::new();
            for i in 0..n {
                let v = random_unit(rng, dim);
                s.add(&v, dummy_feedback(i));
                vectors.push(v);
            }
            let q = random_unit(rng, dim);
            let hits = s.search(&q, k);
            let naive = naive_search(&vectors, &q, k);
            prop::assert_prop(hits.len() == naive.len(), "lengths differ")?;
            for (h, (ni, ns)) in hits.iter().zip(&naive) {
                // scores must agree tightly; ids may differ only on ties
                prop::assert_close(h.score as f64, *ns as f64, 1e-5, "score")?;
                if (h.score - ns).abs() > 1e-6 {
                    prop::assert_prop(h.id == *ni, "id mismatch")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn search_batch_bit_identical_to_singles() {
        // the blocked-kernel batch path must retain exactly the hits of
        // per-query scans — ids, scores, and tie-breaks
        prop::check("flat batch == singles", 30, |rng| {
            let dim = [8, 31, 256][rng.below(3)];
            let n = rng.below(400);
            let k = 1 + rng.below(25);
            let n_q = rng.below(12);
            let mut s = FlatStore::new(dim);
            for i in 0..n {
                s.add(&random_unit(rng, dim), dummy_feedback(i));
            }
            let queries: Vec<Vec<f32>> = (0..n_q).map(|_| random_unit(rng, dim)).collect();
            let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
            let batch = s.search_batch(&qrefs, k);
            prop::assert_prop(batch.len() == n_q, "batch length")?;
            for (q, hits) in qrefs.iter().zip(&batch) {
                prop::assert_prop(hits == &s.search(q, k), "batch hits != single hits")?;
            }
            Ok(())
        });
    }

    #[test]
    fn search_k_larger_than_store() {
        let mut s = FlatStore::new(4);
        s.add(&[1.0, 0.0, 0.0, 0.0], dummy_feedback(0));
        let hits = s.search(&[1.0, 0.0, 0.0, 0.0], 10);
        assert_eq!(hits.len(), 1);
    }

    #[test]
    fn search_empty_store() {
        let s = FlatStore::new(4);
        assert!(s.search(&[1.0, 0.0, 0.0, 0.0], 3).is_empty());
    }

    #[test]
    fn score_all_matches_search_scores() {
        let mut rng = Rng::new(5);
        let mut s = FlatStore::new(32);
        for i in 0..40 {
            s.add(&random_unit(&mut rng, 32), dummy_feedback(i));
        }
        let q = random_unit(&mut rng, 32);
        let dense = s.score_all(&q);
        for h in s.search(&q, 40) {
            assert!((dense[h.id as usize] - h.score).abs() < 1e-6);
        }
    }

    #[test]
    fn payload_roundtrip() {
        let mut s = FlatStore::new(4);
        let fb = dummy_feedback(3);
        let id = s.add(&[0.5, 0.5, 0.5, 0.5], fb.clone());
        assert_eq!(s.feedback(id), &fb);
    }
}
