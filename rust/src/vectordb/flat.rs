//! Exact flat (brute-force) vector index with a blocked scan.
//!
//! Vectors live in one contiguous row-major matrix; the scan walks it in
//! cache-friendly blocks computing dot products with 4-way unrolling and
//! feeds a bounded [`TopK`]. For the corpus sizes RouterBench yields
//! (10^3–10^4 entries at D=256) an exact scan is faster than any index —
//! this is the default request-path store (§Perf).

use super::topk::TopK;
use super::{Feedback, Hit, ReadIndex, VectorIndex};

/// Rows scanned per block; sized so a block (BLOCK_ROWS x 256 f32 = 64 KiB)
/// stays L2-resident.
const BLOCK_ROWS: usize = 64;

/// Exact flat store.
#[derive(Debug, Clone)]
pub struct FlatStore {
    dim: usize,
    data: Vec<f32>,
    payloads: Vec<Feedback>,
}

impl FlatStore {
    pub fn new(dim: usize) -> Self {
        assert!(dim > 0, "dim must be positive");
        FlatStore { dim, data: Vec::new(), payloads: Vec::new() }
    }

    /// Pre-allocate for `capacity` vectors.
    pub fn with_capacity(dim: usize, capacity: usize) -> Self {
        let mut s = Self::new(dim);
        s.data.reserve(capacity * dim);
        s.payloads.reserve(capacity);
        s
    }

    /// Raw row access (used by the IVF builder).
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Scan scoring into a caller-provided TopK (allocation-free reuse).
    pub fn search_into(&self, query: &[f32], topk: &mut TopK) {
        assert_eq!(query.len(), self.dim, "query dim mismatch");
        let n = self.payloads.len();
        let mut base = 0usize;
        while base < n {
            let end = (base + BLOCK_ROWS).min(n);
            for i in base..end {
                let row = &self.data[i * self.dim..(i + 1) * self.dim];
                let s = dot_unrolled(row, query);
                topk.push(i as u32, s);
            }
            base = end;
        }
    }

    /// Dot product of the query against every stored row (dense scores).
    /// Used by tests and by the HLO-scorer agreement checks.
    pub fn score_all(&self, query: &[f32]) -> Vec<f32> {
        assert_eq!(query.len(), self.dim);
        (0..self.payloads.len())
            .map(|i| dot_unrolled(self.row(i), query))
            .collect()
    }
}

/// 4-way unrolled dot product; the scan hot loop.
#[inline]
pub(crate) fn dot_unrolled(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

impl ReadIndex for FlatStore {
    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.payloads.len()
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        let mut topk = TopK::new(k);
        self.search_into(query, &mut topk);
        topk.into_sorted()
            .into_iter()
            .map(|(id, score)| Hit { id, score })
            .collect()
    }

    fn feedback(&self, id: u32) -> &Feedback {
        &self.payloads[id as usize]
    }

    fn vector(&self, id: u32) -> &[f32] {
        self.row(id as usize)
    }
}

impl VectorIndex for FlatStore {
    fn add(&mut self, vector: &[f32], feedback: Feedback) -> u32 {
        assert_eq!(vector.len(), self.dim, "vector dim mismatch");
        let id = self.payloads.len() as u32;
        self.data.extend_from_slice(vector);
        self.payloads.push(feedback);
        id
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;
    use crate::util::{prop, Rng};

    #[test]
    fn add_and_len() {
        let mut s = FlatStore::new(4);
        assert!(s.is_empty());
        let id = s.add(&[1.0, 0.0, 0.0, 0.0], dummy_feedback(0));
        assert_eq!(id, 0);
        assert_eq!(s.len(), 1);
        assert_eq!(s.vector(0), &[1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "dim mismatch")]
    fn rejects_wrong_dim() {
        let mut s = FlatStore::new(4);
        s.add(&[1.0, 0.0], dummy_feedback(0));
    }

    #[test]
    fn search_exact_match_first() {
        let mut rng = Rng::new(1);
        let mut s = FlatStore::new(16);
        let mut vectors = Vec::new();
        for i in 0..50 {
            let v = random_unit(&mut rng, 16);
            s.add(&v, dummy_feedback(i));
            vectors.push(v);
        }
        let hits = s.search(&vectors[17], 5);
        assert_eq!(hits[0].id, 17);
        assert!((hits[0].score - 1.0).abs() < 1e-5);
    }

    #[test]
    fn search_matches_naive_reference() {
        prop::check("flat == naive", 60, |rng| {
            let dim = [8, 16, 256][rng.below(3)];
            let n = 1 + rng.below(300);
            let k = 1 + rng.below(25);
            let mut s = FlatStore::new(dim);
            let mut vectors = Vec::new();
            for i in 0..n {
                let v = random_unit(rng, dim);
                s.add(&v, dummy_feedback(i));
                vectors.push(v);
            }
            let q = random_unit(rng, dim);
            let hits = s.search(&q, k);
            let naive = naive_search(&vectors, &q, k);
            prop::assert_prop(hits.len() == naive.len(), "lengths differ")?;
            for (h, (ni, ns)) in hits.iter().zip(&naive) {
                // scores must agree tightly; ids may differ only on ties
                prop::assert_close(h.score as f64, *ns as f64, 1e-5, "score")?;
                if (h.score - ns).abs() > 1e-6 {
                    prop::assert_prop(h.id == *ni, "id mismatch")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn search_k_larger_than_store() {
        let mut s = FlatStore::new(4);
        s.add(&[1.0, 0.0, 0.0, 0.0], dummy_feedback(0));
        let hits = s.search(&[1.0, 0.0, 0.0, 0.0], 10);
        assert_eq!(hits.len(), 1);
    }

    #[test]
    fn search_empty_store() {
        let s = FlatStore::new(4);
        assert!(s.search(&[1.0, 0.0, 0.0, 0.0], 3).is_empty());
    }

    #[test]
    fn score_all_matches_search_scores() {
        let mut rng = Rng::new(5);
        let mut s = FlatStore::new(32);
        for i in 0..40 {
            s.add(&random_unit(&mut rng, 32), dummy_feedback(i));
        }
        let q = random_unit(&mut rng, 32);
        let dense = s.score_all(&q);
        for h in s.search(&q, 40) {
            assert!((dense[h.id as usize] - h.score).abs() < 1e-6);
        }
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        prop::check("dot unrolled", 100, |rng| {
            let n = rng.below(70);
            let a = prop::vec_f32(rng, n);
            let b = prop::vec_f32(rng, n);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            prop::assert_close(
                dot_unrolled(&a, &b) as f64,
                naive as f64,
                1e-4,
                "dot",
            )
        });
    }

    #[test]
    fn payload_roundtrip() {
        let mut s = FlatStore::new(4);
        let fb = dummy_feedback(3);
        let id = s.add(&[0.5, 0.5, 0.5, 0.5], fb.clone());
        assert_eq!(s.feedback(id), &fb);
    }
}
