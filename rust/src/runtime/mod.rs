//! PJRT runtime: loads the AOT artifacts (HLO text) and executes them.
//!
//! This is the only module that touches the `xla` crate. Flow per artifact
//! (see /opt/xla-example/load_hlo for the reference wiring):
//!
//! ```text
//! HloModuleProto::from_text_file -> XlaComputation::from_proto
//!   -> PjRtClient::compile -> PjRtLoadedExecutable::execute_b
//! ```
//!
//! Model weights are read from `weights.bin` once, transferred to the
//! device once (`buffer_from_host_buffer`), and reused across every embed
//! call — only the token/mask tensors move host->device per request
//! (§Perf: this is what keeps the request path allocation-light).
//!
//! PJRT handles are raw pointers (`!Send`): the embedding service owns a
//! [`Runtime`] on a dedicated engine thread and communicates over channels
//! (see [`crate::embedding`]).
//!
//! The `xla` crate is only linked when the `pjrt` cargo feature is on.
//! Without it this module compiles a stub [`Runtime`] whose `load` fails
//! with a clear message and whose [`Runtime::available`] returns `false`
//! — tests and the serving fallback gate on that, so `cargo test -q` is
//! green on a bare machine with no XLA toolchain. Manifest parsing and
//! weight reading are pure rust and always available.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::json;

/// Model hyper-parameters recorded by the AOT pipeline.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelInfo {
    pub vocab_size: u32,
    pub seq_len: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub seed: u64,
}

/// One weight tensor's layout inside weights.bin.
#[derive(Debug, Clone)]
pub struct TensorRecord {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_elems: usize,
}

impl TensorRecord {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed artifacts/manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelInfo,
    pub embed_batch_sizes: Vec<usize>,
    pub scorer_shapes: Vec<(usize, usize)>,
    pub embed_files: BTreeMap<usize, String>,
    pub scorer_files: BTreeMap<(usize, usize), String>,
    pub weights_file: String,
    pub weights_total_elems: usize,
    pub tensors: Vec<TensorRecord>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;

        let m = v.get("model");
        let model = ModelInfo {
            vocab_size: m.get("vocab_size").as_usize().context("model.vocab_size")? as u32,
            seq_len: m.get("seq_len").as_usize().context("model.seq_len")?,
            d_model: m.get("d_model").as_usize().context("model.d_model")?,
            n_heads: m.get("n_heads").as_usize().context("model.n_heads")?,
            n_layers: m.get("n_layers").as_usize().context("model.n_layers")?,
            d_ff: m.get("d_ff").as_usize().context("model.d_ff")?,
            seed: m.get("seed").as_i64().unwrap_or(0) as u64,
        };

        let mut embed_files = BTreeMap::new();
        let mut scorer_files = BTreeMap::new();
        for art in v.get("artifacts").as_arr().context("artifacts")? {
            let file = art.get("file").as_str().context("artifact.file")?.to_string();
            match art.get("kind").as_str() {
                Some("embed") => {
                    let b = art.get("batch").as_usize().context("artifact.batch")?;
                    embed_files.insert(b, file);
                }
                Some("scorer") => {
                    let q = art.get("queries").as_usize().context("artifact.queries")?;
                    let n = art.get("corpus").as_usize().context("artifact.corpus")?;
                    scorer_files.insert((q, n), file);
                }
                k => bail!("unknown artifact kind {k:?}"),
            }
        }

        let w = v.get("weights");
        let tensors = w
            .get("tensors")
            .as_arr()
            .context("weights.tensors")?
            .iter()
            .map(|t| {
                Ok(TensorRecord {
                    name: t.get("name").as_str().context("tensor.name")?.to_string(),
                    shape: t
                        .get("shape")
                        .as_arr()
                        .context("tensor.shape")?
                        .iter()
                        .map(|d| d.as_usize().context("tensor dim"))
                        .collect::<Result<_>>()?,
                    offset_elems: t.get("offset_elems").as_usize().context("offset")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest {
            dir: dir.to_path_buf(),
            model,
            embed_batch_sizes: embed_files.keys().copied().collect(),
            scorer_shapes: scorer_files.keys().copied().collect(),
            embed_files,
            scorer_files,
            weights_file: w.get("file").as_str().unwrap_or("weights.bin").to_string(),
            weights_total_elems: w.get("total_elems").as_usize().context("total_elems")?,
            tensors,
        })
    }

    /// Smallest compiled batch bucket that fits `n` queries.
    pub fn pick_bucket(&self, n: usize) -> Option<usize> {
        self.embed_batch_sizes.iter().copied().find(|&b| b >= n)
    }

    /// Largest compiled batch bucket.
    pub fn max_bucket(&self) -> usize {
        self.embed_batch_sizes.last().copied().unwrap_or(0)
    }
}

/// Read weights.bin (little-endian f32) and validate its length.
pub fn read_weights(manifest: &Manifest) -> Result<Vec<f32>> {
    let path = manifest.dir.join(&manifest.weights_file);
    let bytes =
        std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() != manifest.weights_total_elems * 4 {
        bail!(
            "{}: expected {} bytes, found {}",
            path.display(),
            manifest.weights_total_elems * 4,
            bytes.len()
        );
    }
    let mut out = Vec::with_capacity(manifest.weights_total_elems);
    for chunk in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    Ok(out)
}

/// A loaded PJRT runtime: compiled executables + device-resident weights.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    embed_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    scorer_exes: BTreeMap<(usize, usize), xla::PjRtLoadedExecutable>,
    weight_bufs: Vec<xla::PjRtBuffer>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// True when the PJRT runtime is compiled into this binary.
    pub fn available() -> bool {
        true
    }

    /// Load every artifact in `dir`, compile, and stage weights on device.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;

        let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).map_err(|e| anyhow!("compile {}: {e}", path.display()))
        };

        let mut embed_exes = BTreeMap::new();
        for (&batch, file) in &manifest.embed_files {
            embed_exes.insert(batch, compile(file)?);
        }
        let mut scorer_exes = BTreeMap::new();
        for (&shape, file) in &manifest.scorer_files {
            scorer_exes.insert(shape, compile(file)?);
        }

        // One-time host->device transfer of all weight tensors.
        let flat = read_weights(&manifest)?;
        let mut weight_bufs = Vec::with_capacity(manifest.tensors.len());
        for t in &manifest.tensors {
            let data = &flat[t.offset_elems..t.offset_elems + t.elems()];
            let buf = client
                .buffer_from_host_buffer::<f32>(data, &t.shape, None)
                .map_err(|e| anyhow!("staging weight {}: {e}", t.name))?;
            weight_bufs.push(buf);
        }

        Ok(Runtime { client, manifest, embed_exes, scorer_exes, weight_bufs })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Embed a padded batch.
    ///
    /// `tokens` is `[batch * seq_len]` i32 row-major, `mask` likewise f32;
    /// `batch` must be a compiled bucket. Returns `[batch * d_model]` f32.
    pub fn embed_batch(&self, tokens: &[i32], mask: &[f32], batch: usize) -> Result<Vec<f32>> {
        let seq = self.manifest.model.seq_len;
        if tokens.len() != batch * seq || mask.len() != batch * seq {
            bail!(
                "embed_batch: expected {}x{} inputs, got tokens={} mask={}",
                batch,
                seq,
                tokens.len(),
                mask.len()
            );
        }
        let exe = self
            .embed_exes
            .get(&batch)
            .ok_or_else(|| anyhow!("no compiled embed bucket for batch {batch}"))?;

        let tok_buf = self
            .client
            .buffer_from_host_buffer::<i32>(tokens, &[batch, seq], None)
            .map_err(|e| anyhow!("tokens upload: {e}"))?;
        let mask_buf = self
            .client
            .buffer_from_host_buffer::<f32>(mask, &[batch, seq], None)
            .map_err(|e| anyhow!("mask upload: {e}"))?;

        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(2 + self.weight_bufs.len());
        args.push(&tok_buf);
        args.push(&mask_buf);
        args.extend(self.weight_bufs.iter());

        let result = exe.execute_b(&args).map_err(|e| anyhow!("embed execute: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("embed readback: {e}"))?
            .to_tuple1()
            .map_err(|e| anyhow!("embed untuple: {e}"))?;
        let out = lit.to_vec::<f32>().map_err(|e| anyhow!("embed to_vec: {e}"))?;
        let d = self.manifest.model.d_model;
        if out.len() != batch * d {
            bail!("embed output: expected {} floats, got {}", batch * d, out.len());
        }
        Ok(out)
    }

    /// Score `q_n` queries against a corpus slab via the Pallas scorer HLO.
    ///
    /// `queries` is `[q_n * d]`, `corpus` is `[n * d]`; `(q_n, n)` must be a
    /// compiled bucket. Returns `[q_n * n]` scores.
    pub fn score(&self, queries: &[f32], q_n: usize, corpus: &[f32], n: usize) -> Result<Vec<f32>> {
        let d = self.manifest.model.d_model;
        if queries.len() != q_n * d || corpus.len() != n * d {
            bail!("score: bad input lengths");
        }
        let exe = self
            .scorer_exes
            .get(&(q_n, n))
            .ok_or_else(|| anyhow!("no compiled scorer bucket for ({q_n},{n})"))?;
        let q_buf = self
            .client
            .buffer_from_host_buffer::<f32>(queries, &[q_n, d], None)
            .map_err(|e| anyhow!("queries upload: {e}"))?;
        let c_buf = self
            .client
            .buffer_from_host_buffer::<f32>(corpus, &[n, d], None)
            .map_err(|e| anyhow!("corpus upload: {e}"))?;
        let result = exe
            .execute_b(&[&q_buf, &c_buf])
            .map_err(|e| anyhow!("score execute: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("score readback: {e}"))?
            .to_tuple1()
            .map_err(|e| anyhow!("score untuple: {e}"))?;
        let out = lit.to_vec::<f32>().map_err(|e| anyhow!("score to_vec: {e}"))?;
        if out.len() != q_n * n {
            bail!("score output: expected {} floats, got {}", q_n * n, out.len());
        }
        Ok(out)
    }
}

/// Stub runtime compiled when the `pjrt` feature is off: loading always
/// fails (after surfacing manifest problems first, so error paths match),
/// and [`Runtime::available`] lets callers skip cleanly.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// False: the PJRT runtime is not compiled into this binary.
    pub fn available() -> bool {
        false
    }

    /// Validates the manifest (so corrupt-artifact errors surface the same
    /// way as in the real runtime), then fails with a clear message.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let _manifest = Manifest::load(dir)?;
        bail!(
            "PJRT runtime unavailable: this binary was built without the \
             `pjrt` cargo feature (the xla crate is not linked). Rebuild \
             with `--features pjrt` in an environment that provides it."
        )
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform_name(&self) -> String {
        "pjrt-unavailable".to_string()
    }

    pub fn embed_batch(&self, _tokens: &[i32], _mask: &[f32], _batch: usize) -> Result<Vec<f32>> {
        bail!("PJRT runtime unavailable (built without the `pjrt` feature)")
    }

    pub fn score(
        &self,
        _queries: &[f32],
        _q_n: usize,
        _corpus: &[f32],
        _n: usize,
    ) -> Result<Vec<f32>> {
        bail!("PJRT runtime unavailable (built without the `pjrt` feature)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full Runtime tests live in rust/tests/runtime_integration.rs (they
    // need built artifacts). Here: manifest parsing over a synthetic dir.

    fn write_fake_manifest(dir: &Path, total_elems: usize) {
        let manifest = format!(
            r#"{{
  "format_version": 1,
  "model": {{"vocab_size": 64, "seq_len": 8, "d_model": 16, "n_heads": 2,
             "n_layers": 1, "d_ff": 32, "seed": 1}},
  "embed_batch_sizes": [1, 4],
  "scorer_shapes": [[1, 128]],
  "artifacts": [
    {{"name": "embed_b1", "kind": "embed", "file": "embed_b1.hlo.txt", "batch": 1,
      "seq_len": 8, "out_dim": 16}},
    {{"name": "embed_b4", "kind": "embed", "file": "embed_b4.hlo.txt", "batch": 4,
      "seq_len": 8, "out_dim": 16}},
    {{"name": "scorer_q1_n128", "kind": "scorer", "file": "s.hlo.txt",
      "queries": 1, "corpus": 128, "dim": 16}}
  ],
  "weights": {{"file": "weights.bin", "dtype": "f32_le", "total_elems": {total_elems},
    "sha256": "x",
    "tensors": [{{"name": "a", "shape": [2, 4], "offset_elems": 0}},
                 {{"name": "b", "shape": [4], "offset_elems": 8}}]}}
}}"#
        );
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("eagle_rt_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn manifest_parses() {
        let dir = tmpdir("parse");
        write_fake_manifest(&dir, 12);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.d_model, 16);
        assert_eq!(m.embed_batch_sizes, vec![1, 4]);
        assert_eq!(m.scorer_shapes, vec![(1, 128)]);
        assert_eq!(m.tensors.len(), 2);
        assert_eq!(m.tensors[1].offset_elems, 8);
    }

    #[test]
    fn pick_bucket_smallest_fitting() {
        let dir = tmpdir("bucket");
        write_fake_manifest(&dir, 12);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.pick_bucket(1), Some(1));
        assert_eq!(m.pick_bucket(2), Some(4));
        assert_eq!(m.pick_bucket(4), Some(4));
        assert_eq!(m.pick_bucket(5), None);
        assert_eq!(m.max_bucket(), 4);
    }

    #[test]
    fn read_weights_validates_length() {
        let dir = tmpdir("weights");
        write_fake_manifest(&dir, 12);
        std::fs::write(dir.join("weights.bin"), vec![0u8; 12 * 4]).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let w = read_weights(&m).unwrap();
        assert_eq!(w.len(), 12);

        std::fs::write(dir.join("weights.bin"), vec![0u8; 11 * 4]).unwrap();
        assert!(read_weights(&m).is_err());
    }

    #[test]
    fn weights_little_endian_decode() {
        let dir = tmpdir("le");
        write_fake_manifest(&dir, 12);
        let mut bytes = Vec::new();
        for i in 0..12 {
            bytes.extend_from_slice(&(i as f32 * 0.5).to_le_bytes());
        }
        std::fs::write(dir.join("weights.bin"), bytes).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let w = read_weights(&m).unwrap();
        assert_eq!(w[3], 1.5);
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = tmpdir("missing");
        assert!(Manifest::load(&dir).is_err());
    }
}
